//! Durable run state: atomic, versioned, checksummed whole-run
//! checkpoints with a retained generation chain (DESIGN.md §16).
//!
//! A [`RunCheckpoint`] captures *everything* a growth run mutates —
//! parameters, Adam moments, every live RNG (boundary-surgery generator
//! and batcher cursor), cross-segment counters, the growth policy's
//! internal state, the current segment index, and the last applied
//! [`ExpansionPlan`](crate::expand::ExpansionPlan) as evidence — so that
//! `texpand train --resume` replays the exact trajectory an uninterrupted
//! run would have taken, bit for bit (the determinism guarantees from the
//! parallel-training and policy work make that a checkable property, not
//! an aspiration).
//!
//! ## Container format (`TXCK` version 1)
//!
//! ```text
//! magic "TXCK" | u32 version (LE) | u64 header_len (LE) | u32 header_crc32 (LE)
//! | header JSON (header_len bytes) | payload sections (concatenated)
//! ```
//!
//! The header carries all scalar state plus a `sections` table — one
//! entry per tensor store (`params`, `adam_m`, `adam_v`) with its byte
//! length and CRC-32. Tensor payloads are raw f32 little-endian in the
//! [`ParamStore`] canonical spec order; no per-tensor framing is needed
//! because the header's `config` determines every spec. Exactness rules:
//! `u64`/`f64`-bit values are hex strings (JSON numbers cap at 2^53);
//! `f32` values round-trip exactly through the shortest-representation
//! float formatter the [`crate::json`] writer uses.
//!
//! ## Atomicity and the generation chain
//!
//! [`Chain`] writes `gen-NNNNNN.txck` files via tmp + `fsync` + `rename`
//! (+ parent-dir fsync), keeping the last K generations. A crash mid-write
//! leaves only a `.tmp` the chain ignores; a torn or bit-flipped file
//! fails its CRC at load and [`Chain::load_latest_valid`] falls back to
//! the previous good generation with a warning.

pub mod chain;
pub mod checksum;

pub use chain::Chain;

use crate::config::{ModelConfig, OptimKind, TrainConfig};
use crate::data::Batcher;
use crate::error::{Error, Result};
use crate::growth::GrowthPolicy;
use crate::json::Value;
use crate::metrics::{RunLogger, Timer};
use crate::optim::Optimizer;
use crate::params::ParamStore;
use crate::train::TrainState;

pub const MAGIC: &[u8; 4] = b"TXCK";
pub const VERSION: u32 = 1;

// ---------------------------------------------------------------------------
// hex codecs for values JSON numbers can't carry exactly
// ---------------------------------------------------------------------------

fn hex_u64(v: u64) -> Value {
    Value::str(format!("{v:016x}"))
}

fn parse_hex_u64(v: &Value, what: &str) -> Result<u64> {
    let s = v.as_str()?;
    u64::from_str_radix(s, 16)
        .map_err(|_| Error::Checkpoint(format!("{what}: bad hex u64 {s:?}")))
}

fn hex_f64(v: f64) -> Value {
    hex_u64(v.to_bits())
}

fn parse_hex_f64(v: &Value, what: &str) -> Result<f64> {
    Ok(f64::from_bits(parse_hex_u64(v, what)?))
}

/// `(state, inc, spare_normal)` RNG parts ⇄ JSON (see [`crate::rng::Pcg32::to_parts`]).
fn rng_to_json(parts: (u64, u64, Option<f64>)) -> Value {
    Value::obj(vec![
        ("state", hex_u64(parts.0)),
        ("inc", hex_u64(parts.1)),
        ("spare_bits", match parts.2 {
            Some(z) => hex_f64(z),
            None => Value::Null,
        }),
    ])
}

fn rng_from_json(v: &Value, what: &str) -> Result<(u64, u64, Option<f64>)> {
    let state = parse_hex_u64(v.req("state")?, what)?;
    let inc = parse_hex_u64(v.req("inc")?, what)?;
    let spare = match v.req("spare_bits")? {
        Value::Null => None,
        bits => Some(parse_hex_f64(bits, what)?),
    };
    Ok((state, inc, spare))
}

// ---------------------------------------------------------------------------
// RunCheckpoint
// ---------------------------------------------------------------------------

/// Complete run state at one recovery point. See module docs for the
/// on-disk format; [`RunCheckpoint::save`]/[`RunCheckpoint::load`] are the
/// codec, [`Chain`] manages the retained generations.
#[derive(Clone, Debug)]
pub struct RunCheckpoint {
    /// Run identity (schedule/policy/seed/corpus/batch/steps-scale) — a
    /// resume against a different configuration is rejected up front
    /// instead of silently diverging.
    pub fingerprint: Value,
    pub global_step: usize,
    pub tokens_seen: usize,
    pub est_flops: f64,
    /// Segment index (`stageN`) the run was in when captured.
    pub segment: usize,
    /// Steps already completed *within* the current segment — the policy
    /// observation cadence (`arch_step`) resumes from here.
    pub local_step: usize,
    /// Boundary-surgery RNG (constant during a segment; advances only at
    /// expansion boundaries).
    pub surgery_rng: (u64, u64, Option<f64>),
    /// The batcher's draw cursor; the token stream itself is rebuilt
    /// deterministically from the fingerprinted corpus parameters.
    pub batcher_rng: (u64, u64, Option<f64>),
    /// Name of the policy that produced `policy_state`.
    pub policy: String,
    /// Opaque policy snapshot ([`GrowthPolicy::snapshot`]).
    pub policy_state: Value,
    /// `"adam"` or `"sgd"`.
    pub opt_kind: String,
    /// Adam update count (bias correction); 0 for SGD.
    pub adam_t: u64,
    /// The last applied expansion plan (evidence for the timeline; `None`
    /// before the first boundary).
    pub last_plan: Option<Value>,
    pub params: ParamStore,
    pub adam_m: Option<ParamStore>,
    pub adam_v: Option<ParamStore>,
}

impl RunCheckpoint {
    pub fn config(&self) -> &ModelConfig {
        self.params.config()
    }

    /// Rebuild the optimizer this checkpoint captured. Hyperparameters
    /// come from the live `tcfg` (they are not run state); the moment
    /// stores and update count come from the checkpoint.
    pub fn to_optimizer(&self, tcfg: &TrainConfig) -> Result<Optimizer> {
        let want = match tcfg.optimizer {
            OptimKind::Adam => "adam",
            OptimKind::Sgd => "sgd",
        };
        if want != self.opt_kind {
            return Err(Error::Checkpoint(format!(
                "checkpoint captured a {} optimizer but the run is configured for {want}",
                self.opt_kind
            )));
        }
        match self.opt_kind.as_str() {
            "sgd" => Ok(Optimizer::Sgd { lr: tcfg.lr }),
            "adam" => {
                let (m, v) = match (&self.adam_m, &self.adam_v) {
                    (Some(m), Some(v)) => (m.clone(), v.clone()),
                    _ => {
                        return Err(Error::Checkpoint(
                            "adam checkpoint is missing moment sections".into(),
                        ))
                    }
                };
                Ok(Optimizer::Adam {
                    lr: tcfg.lr,
                    beta1: tcfg.beta1,
                    beta2: tcfg.beta2,
                    eps: tcfg.adam_eps,
                    t: self.adam_t,
                    m,
                    v,
                })
            }
            other => Err(Error::Checkpoint(format!("unknown optimizer kind {other:?}"))),
        }
    }

    fn header(&self, sections: &[(String, u32, usize)]) -> Value {
        Value::obj(vec![
            ("fingerprint", self.fingerprint.clone()),
            (
                "state",
                Value::obj(vec![
                    ("global_step", Value::num(self.global_step as f64)),
                    ("tokens_seen", Value::num(self.tokens_seen as f64)),
                    ("est_flops_bits", hex_f64(self.est_flops)),
                    ("segment", Value::num(self.segment as f64)),
                    ("local_step", Value::num(self.local_step as f64)),
                ]),
            ),
            ("config", self.params.config().to_json()),
            (
                "rng",
                Value::obj(vec![
                    ("surgery", rng_to_json(self.surgery_rng)),
                    ("batcher", rng_to_json(self.batcher_rng)),
                ]),
            ),
            (
                "policy",
                Value::obj(vec![
                    ("name", Value::str(self.policy.clone())),
                    ("state", self.policy_state.clone()),
                ]),
            ),
            (
                "optimizer",
                Value::obj(vec![
                    ("kind", Value::str(self.opt_kind.clone())),
                    ("t", hex_u64(self.adam_t)),
                ]),
            ),
            ("last_plan", self.last_plan.clone().unwrap_or(Value::Null)),
            (
                "sections",
                Value::Arr(
                    sections
                        .iter()
                        .map(|(name, crc, bytes)| {
                            Value::obj(vec![
                                ("name", Value::str(name.clone())),
                                ("crc32", Value::num(*crc as f64)),
                                ("bytes", Value::num(*bytes as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Serialize into the `TXCK` container and write it atomically
    /// (tmp + fsync + rename + parent-dir fsync). Returns the byte size.
    /// Fault points `ckpt_mid_write` / `ckpt_pre_rename` sit inside
    /// ([`crate::faults`]) for the crash-recovery tests.
    pub fn save(&self, path: &str) -> Result<u64> {
        use std::io::Write;

        let mut stores: Vec<(&str, &ParamStore)> = vec![("params", &self.params)];
        if let Some(m) = &self.adam_m {
            stores.push(("adam_m", m));
        }
        if let Some(v) = &self.adam_v {
            stores.push(("adam_v", v));
        }

        let mut payload = Vec::new();
        let mut sections = Vec::new();
        for (name, store) in &stores {
            let start = payload.len();
            for t in store.tensors() {
                for v in t.data() {
                    payload.extend_from_slice(&v.to_le_bytes());
                }
            }
            let body = &payload[start..];
            sections.push((name.to_string(), checksum::crc32(body), body.len()));
        }

        let header = self.header(&sections).to_string().into_bytes();
        let mut doc = Vec::with_capacity(4 + 4 + 8 + 4 + header.len() + payload.len());
        doc.extend_from_slice(MAGIC);
        doc.extend_from_slice(&VERSION.to_le_bytes());
        doc.extend_from_slice(&(header.len() as u64).to_le_bytes());
        doc.extend_from_slice(&checksum::crc32(&header).to_le_bytes());
        doc.extend_from_slice(&header);
        doc.extend_from_slice(&payload);

        let tmp = format!("{path}.tmp");
        let io = |e: std::io::Error| Error::io(&tmp, e);
        {
            let mut f = std::fs::File::create(&tmp).map_err(io)?;
            // two-phase write with the mid-write fault point between: an
            // injected crash here leaves a torn tmp file on disk, which the
            // chain must ignore and the checksum must reject
            let half = doc.len() / 2;
            f.write_all(&doc[..half]).map_err(io)?;
            f.flush().map_err(io)?;
            crate::faults::fault_point("ckpt_mid_write");
            f.write_all(&doc[half..]).map_err(io)?;
            // the durability point: file contents reach disk before the
            // rename can expose them under the real name
            f.sync_all().map_err(io)?;
        }
        crate::faults::fault_point("ckpt_pre_rename");
        std::fs::rename(&tmp, path).map_err(|e| Error::io(path, e))?;
        // fsync the directory so the rename itself survives power loss
        if let Some(parent) = std::path::Path::new(path).parent() {
            if let Ok(d) = std::fs::File::open(parent) {
                let _ = d.sync_all();
            }
        }
        Ok(doc.len() as u64)
    }

    /// Parse and checksum-validate a `TXCK` container. Any torn write,
    /// truncation or bit flip surfaces as `Error::Checkpoint` — the chain
    /// treats that as "this generation is bad, try the previous one".
    pub fn load(path: &str) -> Result<RunCheckpoint> {
        let doc = std::fs::read(path).map_err(|e| Error::io(path, e))?;
        let bad = |msg: String| Error::Checkpoint(format!("{path}: {msg}"));
        if doc.len() < 20 || &doc[0..4] != MAGIC {
            return Err(bad("not a TXCK checkpoint (bad magic or truncated)".into()));
        }
        let version = u32::from_le_bytes(doc[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(bad(format!("unsupported version {version} (expected {VERSION})")));
        }
        let header_len = u64::from_le_bytes(doc[8..16].try_into().unwrap()) as usize;
        let header_crc = u32::from_le_bytes(doc[16..20].try_into().unwrap());
        let header_end = 20usize
            .checked_add(header_len)
            .filter(|&e| e <= doc.len())
            .ok_or_else(|| bad("truncated header".into()))?;
        let header_bytes = &doc[20..header_end];
        if checksum::crc32(header_bytes) != header_crc {
            return Err(bad("header checksum mismatch".into()));
        }
        let header = Value::parse(
            std::str::from_utf8(header_bytes).map_err(|_| bad("header is not UTF-8".into()))?,
        )?;

        let config = ModelConfig::from_json(header.req("config")?)?;
        let state = header.req("state")?;
        let rng = header.req("rng")?;
        let pol = header.req("policy")?;
        let optv = header.req("optimizer")?;

        // payload sections, each validated against its own checksum
        let mut cursor = header_end;
        let mut params = None;
        let mut adam_m = None;
        let mut adam_v = None;
        for sec in header.req("sections")?.as_arr()? {
            let name = sec.req("name")?.as_str()?;
            let bytes = sec.req("bytes")?.as_usize()?;
            let crc = sec.req("crc32")?.as_i64()? as u32;
            let end = cursor
                .checked_add(bytes)
                .filter(|&e| e <= doc.len())
                .ok_or_else(|| bad(format!("section '{name}' truncated")))?;
            let body = &doc[cursor..end];
            cursor = end;
            if checksum::crc32(body) != crc {
                return Err(bad(format!("section '{name}' checksum mismatch")));
            }
            let mut store = ParamStore::zeros(&config);
            if bytes != store.num_scalars() * 4 {
                return Err(bad(format!(
                    "section '{name}' holds {bytes} bytes but the config needs {}",
                    store.num_scalars() * 4
                )));
            }
            let mut off = 0;
            for t in store.tensors_mut() {
                for v in t.data_mut() {
                    *v = f32::from_le_bytes(body[off..off + 4].try_into().unwrap());
                    off += 4;
                }
            }
            match name {
                "params" => params = Some(store),
                "adam_m" => adam_m = Some(store),
                "adam_v" => adam_v = Some(store),
                other => return Err(bad(format!("unknown section '{other}'"))),
            }
        }
        if cursor != doc.len() {
            return Err(bad(format!("{} trailing bytes after sections", doc.len() - cursor)));
        }
        let params = params.ok_or_else(|| bad("missing 'params' section".into()))?;

        Ok(RunCheckpoint {
            fingerprint: header.req("fingerprint")?.clone(),
            global_step: state.req("global_step")?.as_usize()?,
            tokens_seen: state.req("tokens_seen")?.as_usize()?,
            est_flops: parse_hex_f64(state.req("est_flops_bits")?, "est_flops")?,
            segment: state.req("segment")?.as_usize()?,
            local_step: state.req("local_step")?.as_usize()?,
            surgery_rng: rng_from_json(rng.req("surgery")?, "surgery rng")?,
            batcher_rng: rng_from_json(rng.req("batcher")?, "batcher rng")?,
            policy: pol.req("name")?.as_str()?.to_string(),
            policy_state: pol.req("state")?.clone(),
            opt_kind: optv.req("kind")?.as_str()?.to_string(),
            adam_t: parse_hex_u64(optv.req("t")?, "adam t")?,
            last_plan: match header.req("last_plan")? {
                Value::Null => None,
                plan => Some(plan.clone()),
            },
            params,
            adam_m,
            adam_v,
        })
    }
}

// ---------------------------------------------------------------------------
// CkptHook — the training-loop attachment point
// ---------------------------------------------------------------------------

/// Checkpoint writer threaded through `train_segment` / the coordinator.
///
/// Owns the generation [`Chain`] plus the per-segment context the inner
/// loop can't see (run fingerprint, segment index, boundary-surgery RNG
/// snapshot, last applied plan). The coordinator refreshes the segment
/// fields before each segment and forces a write at every expansion
/// boundary; the training loop calls [`CkptHook::maybe_write`] after each
/// completed optimizer step.
pub struct CkptHook {
    pub chain: Chain,
    /// Write every N global steps (0 = only forced boundary checkpoints).
    pub every: usize,
    pub fingerprint: Value,
    pub segment: usize,
    pub surgery_rng: (u64, u64, Option<f64>),
    pub last_plan: Option<Value>,
    /// Segment-local step to resume the next segment's loop at (consumed
    /// once by `train_segment`; 0 for fresh segments).
    resume_local_step: usize,
}

impl CkptHook {
    pub fn new(chain: Chain, every: usize, fingerprint: Value) -> CkptHook {
        CkptHook {
            chain,
            every,
            fingerprint,
            segment: 0,
            surgery_rng: (0, 0, None),
            last_plan: None,
            resume_local_step: 0,
        }
    }

    /// Arm the next `train_segment` call to start its local step counter
    /// mid-segment (the resume path).
    pub fn set_resume_local_step(&mut self, step: usize) {
        self.resume_local_step = step;
    }

    /// One-shot consumption by `train_segment` at loop entry.
    pub fn take_resume_local_step(&mut self) -> usize {
        std::mem::take(&mut self.resume_local_step)
    }

    /// Interval trigger: write when `--checkpoint-every` divides the
    /// global step. Called after the optimizer update and state bump, so
    /// the captured state is "step N fully applied, step N+1 not started".
    #[allow(clippy::too_many_arguments)]
    pub fn maybe_write(
        &mut self,
        local_step: usize,
        params: &ParamStore,
        opt: &Optimizer,
        batcher: &Batcher,
        policy: &dyn GrowthPolicy,
        state: &TrainState,
        logger: &mut RunLogger,
    ) -> Result<()> {
        if self.every == 0 || state.global_step % self.every != 0 {
            return Ok(());
        }
        self.write("interval", local_step, params, opt, batcher, policy, state, logger)
    }

    /// Capture and durably write one generation, then log/instrument it.
    #[allow(clippy::too_many_arguments)]
    pub fn write(
        &mut self,
        trigger: &str,
        local_step: usize,
        params: &ParamStore,
        opt: &Optimizer,
        batcher: &Batcher,
        policy: &dyn GrowthPolicy,
        state: &TrainState,
        logger: &mut RunLogger,
    ) -> Result<()> {
        let (opt_kind, adam_t, adam_m, adam_v) = match opt {
            Optimizer::Sgd { .. } => ("sgd", 0, None, None),
            Optimizer::Adam { t, m, v, .. } => ("adam", *t, Some(m.clone()), Some(v.clone())),
        };
        let ck = RunCheckpoint {
            fingerprint: self.fingerprint.clone(),
            global_step: state.global_step,
            tokens_seen: state.tokens_seen,
            est_flops: state.est_flops,
            segment: self.segment,
            local_step,
            surgery_rng: self.surgery_rng,
            batcher_rng: batcher.rng_parts(),
            policy: policy.name().to_string(),
            policy_state: policy.snapshot(),
            opt_kind: opt_kind.to_string(),
            adam_t,
            last_plan: self.last_plan.clone(),
            params: params.clone(),
            adam_m,
            adam_v,
        };
        let timer = Timer::start();
        let (gen, bytes) = self.chain.save(&ck)?;
        let write_ms = timer.ms();

        let reg = crate::obs::global();
        reg.counter("texpand_checkpoints_total", "Checkpoint generations written").inc();
        reg.histogram(
            "texpand_checkpoint_write_ms",
            "Checkpoint serialize+fsync+rename duration (ms)",
            &crate::obs::LATENCY_MS_BOUNDS,
        )
        .observe(write_ms);
        logger.event(
            "checkpoint",
            vec![
                ("gen", Value::num(gen as f64)),
                ("trigger", Value::str(trigger)),
                ("global_step", Value::num(state.global_step as f64)),
                ("segment", Value::num(self.segment as f64)),
                ("bytes", Value::num(bytes as f64)),
                ("write_ms", Value::num(write_ms)),
            ],
        );
        // a recovery point that isn't on disk when the crash comes is no
        // recovery point: flush the log with the checkpoint
        logger.flush();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { layers: 1, hidden: 8, heads: 2, k: 4, v: 4, mlp: 16, seq: 8, vocab: 32 }
    }

    fn sample_checkpoint() -> RunCheckpoint {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(11);
        let params = ParamStore::init(&cfg, &mut rng, 0.02);
        let m = ParamStore::init(&cfg, &mut rng, 0.001);
        let v = ParamStore::init(&cfg, &mut rng, 0.0001);
        let mut surgery = Pcg32::seeded(3);
        let _ = surgery.normal(); // populate the spare so it round-trips
        RunCheckpoint {
            fingerprint: Value::obj(vec![("schedule", Value::str("t"))]),
            global_step: 123,
            tokens_seen: 4567,
            est_flops: 8.9e12,
            segment: 2,
            local_step: 17,
            surgery_rng: surgery.to_parts(),
            batcher_rng: Pcg32::new(9, 0xBA7C).to_parts(),
            policy: "fixed".into(),
            policy_state: Value::obj(vec![("fired", Value::num(1.0))]),
            opt_kind: "adam".into(),
            adam_t: 123,
            last_plan: Some(Value::obj(vec![("ops", Value::Arr(vec![]))])),
            params,
            adam_m: Some(m),
            adam_v: Some(v),
        }
    }

    fn tmp_path(tag: &str) -> String {
        let dir = std::env::temp_dir().join(format!("texpand-ckpt-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("ck.txck").to_str().unwrap().to_string()
    }

    #[test]
    fn round_trip_is_bit_identical() {
        let ck = sample_checkpoint();
        let path = tmp_path("roundtrip");
        ck.save(&path).unwrap();
        let back = RunCheckpoint::load(&path).unwrap();
        assert_eq!(back.global_step, ck.global_step);
        assert_eq!(back.tokens_seen, ck.tokens_seen);
        assert_eq!(back.est_flops.to_bits(), ck.est_flops.to_bits());
        assert_eq!(back.segment, ck.segment);
        assert_eq!(back.local_step, ck.local_step);
        assert_eq!(back.surgery_rng, ck.surgery_rng);
        assert_eq!(back.batcher_rng, ck.batcher_rng);
        assert_eq!(back.policy, ck.policy);
        assert_eq!(back.policy_state.to_string(), ck.policy_state.to_string());
        assert_eq!(back.opt_kind, ck.opt_kind);
        assert_eq!(back.adam_t, ck.adam_t);
        assert_eq!(
            back.last_plan.as_ref().map(|p| p.to_string()),
            ck.last_plan.as_ref().map(|p| p.to_string())
        );
        for (want, got) in [
            (&ck.params, &back.params),
            (ck.adam_m.as_ref().unwrap(), back.adam_m.as_ref().unwrap()),
            (ck.adam_v.as_ref().unwrap(), back.adam_v.as_ref().unwrap()),
        ] {
            assert_eq!(want.config(), got.config());
            for ((sa, ta), (_, tb)) in want.iter().zip(got.iter()) {
                for (a, b) in ta.data().iter().zip(tb.data()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "param {} differs", sa.name);
                }
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sgd_checkpoint_omits_moment_sections() {
        let mut ck = sample_checkpoint();
        ck.opt_kind = "sgd".into();
        ck.adam_t = 0;
        ck.adam_m = None;
        ck.adam_v = None;
        let path = tmp_path("sgd");
        ck.save(&path).unwrap();
        let back = RunCheckpoint::load(&path).unwrap();
        assert!(back.adam_m.is_none() && back.adam_v.is_none());
        let opt = back.to_optimizer(&TrainConfig { optimizer: OptimKind::Sgd, ..Default::default() }).unwrap();
        assert!(matches!(opt, Optimizer::Sgd { .. }));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn to_optimizer_rejects_kind_mismatch() {
        let ck = sample_checkpoint(); // adam
        let sgd_cfg = TrainConfig { optimizer: OptimKind::Sgd, ..Default::default() };
        assert!(ck.to_optimizer(&sgd_cfg).is_err());
        let adam = ck.to_optimizer(&TrainConfig::default()).unwrap();
        match adam {
            Optimizer::Adam { t, .. } => assert_eq!(t, 123),
            _ => panic!("expected adam"),
        }
    }

    #[test]
    fn every_corrupted_byte_region_is_detected() {
        let ck = sample_checkpoint();
        let path = tmp_path("corrupt");
        ck.save(&path).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // flip one bit in several structurally distinct regions: magic,
        // version, header json, each payload section
        for pos in [0usize, 5, 25, clean.len() / 2, clean.len() - 1] {
            let mut bad = clean.clone();
            bad[pos] ^= 0x10;
            std::fs::write(&path, &bad).unwrap();
            assert!(
                RunCheckpoint::load(&path).is_err(),
                "bit flip at byte {pos} loaded successfully"
            );
        }
        // truncation at any boundary is also rejected
        for cut in [3usize, 19, clean.len() / 3, clean.len() - 1] {
            std::fs::write(&path, &clean[..cut]).unwrap();
            assert!(RunCheckpoint::load(&path).is_err(), "truncation to {cut} bytes loaded");
        }
        std::fs::remove_file(&path).ok();
    }
}
