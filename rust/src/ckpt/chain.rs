//! Retained checkpoint generation chain (DESIGN.md §16.4).
//!
//! One directory holds `gen-NNNNNN.txck` files, numbered monotonically.
//! [`Chain::save`] writes the next generation atomically and prunes down
//! to the last K; [`Chain::load_latest_valid`] walks generations newest
//! to oldest, skipping (with a warning) any that fail checksum validation
//! — so a torn or bit-flipped latest file degrades to "resume from the
//! previous good recovery point" rather than an abort. Only when *every*
//! retained generation is corrupt does the load error out: silently
//! restarting from scratch would overwrite the evidence the operator
//! needs.

use std::path::{Path, PathBuf};

use super::RunCheckpoint;
use crate::error::{Error, Result};

pub struct Chain {
    dir: PathBuf,
    /// Number of generations retained after each save (≥ 1).
    keep: usize,
}

fn gen_of(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("gen-")?.strip_suffix(".txck")?;
    if digits.len() == 6 {
        digits.parse().ok()
    } else {
        None
    }
}

impl Chain {
    /// Open (creating if needed) a chain directory.
    pub fn open(dir: &Path, keep: usize) -> Result<Chain> {
        std::fs::create_dir_all(dir).map_err(|e| Error::io(&dir.display().to_string(), e))?;
        Ok(Chain { dir: dir.to_path_buf(), keep: keep.max(1) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// On-disk path of generation `gen` (whether or not it exists yet).
    pub fn path_of(&self, gen: u64) -> PathBuf {
        self.dir.join(format!("gen-{gen:06}.txck"))
    }

    /// Generation numbers currently on disk, ascending. Stale `.tmp`
    /// leftovers from a crashed write are ignored.
    pub fn generations(&self) -> Result<Vec<u64>> {
        let rd = std::fs::read_dir(&self.dir)
            .map_err(|e| Error::io(&self.dir.display().to_string(), e))?;
        let mut gens: Vec<u64> = rd
            .filter_map(|ent| ent.ok())
            .filter_map(|ent| gen_of(&ent.file_name().to_string_lossy()))
            .collect();
        gens.sort_unstable();
        Ok(gens)
    }

    /// Write the next generation atomically, prune to the last `keep`,
    /// and sweep any stale `.tmp` files. Returns `(gen, bytes_written)`.
    pub fn save(&self, ck: &RunCheckpoint) -> Result<(u64, u64)> {
        let gens = self.generations()?;
        let gen = gens.last().map_or(1, |g| g + 1);
        let path = self.path_of(gen);
        let bytes = ck.save(path.to_str().ok_or_else(|| {
            Error::Checkpoint(format!("non-UTF-8 checkpoint path {}", path.display()))
        })?)?;
        // prune oldest generations beyond the retention window
        let mut all = gens;
        all.push(gen);
        while all.len() > self.keep {
            let victim = all.remove(0);
            std::fs::remove_file(self.path_of(victim)).ok();
        }
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for ent in rd.filter_map(|e| e.ok()) {
                if ent.file_name().to_string_lossy().ends_with(".tmp") {
                    std::fs::remove_file(ent.path()).ok();
                }
            }
        }
        Ok((gen, bytes))
    }

    /// Newest checkpoint that passes full checksum validation, or
    /// `Ok(None)` for an empty chain. Corrupt generations are skipped
    /// with a warning on stderr; if generations exist but *all* are
    /// corrupt, that is an error, not a silent fresh start.
    pub fn load_latest_valid(&self) -> Result<Option<(u64, RunCheckpoint)>> {
        let gens = self.generations()?;
        if gens.is_empty() {
            return Ok(None);
        }
        let mut last_err = None;
        for &gen in gens.iter().rev() {
            let path = self.path_of(gen);
            match RunCheckpoint::load(&path.display().to_string()) {
                Ok(ck) => return Ok(Some((gen, ck))),
                Err(e) => {
                    eprintln!(
                        "warning: checkpoint generation {gen} is unreadable ({e}); \
                         falling back to the previous generation"
                    );
                    last_err = Some(e);
                }
            }
        }
        Err(Error::Checkpoint(format!(
            "all {} retained checkpoint generations in {} are corrupt (last error: {})",
            gens.len(),
            self.dir.display(),
            last_err.expect("non-empty chain had no error")
        )))
    }

    /// Delete every retained generation (fresh, non-resume run start).
    pub fn reset(&self) -> Result<()> {
        for gen in self.generations()? {
            let p = self.path_of(gen);
            std::fs::remove_file(&p).map_err(|e| Error::io(&p.display().to_string(), e))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Value;
    use crate::params::ParamStore;
    use crate::rng::Pcg32;

    fn ck(step: usize) -> RunCheckpoint {
        let cfg = crate::config::ModelConfig {
            layers: 1,
            hidden: 8,
            heads: 2,
            k: 4,
            v: 4,
            mlp: 16,
            seq: 8,
            vocab: 32,
        };
        let mut rng = Pcg32::seeded(step as u64 + 1);
        RunCheckpoint {
            fingerprint: Value::obj(vec![("schedule", Value::str("t"))]),
            global_step: step,
            tokens_seen: step * 64,
            est_flops: step as f64,
            segment: 0,
            local_step: step,
            surgery_rng: (1, 3, None),
            batcher_rng: (5, 7, None),
            policy: "fixed".into(),
            policy_state: Value::Null,
            opt_kind: "sgd".into(),
            adam_t: 0,
            last_plan: None,
            params: ParamStore::init(&cfg, &mut rng, 0.02),
            adam_m: None,
            adam_v: None,
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("texpand-chain-{}-{tag}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn save_prunes_to_keep_and_resumes_latest() {
        let dir = tmp_dir("prune");
        let chain = Chain::open(&dir, 3).unwrap();
        assert!(chain.load_latest_valid().unwrap().is_none());
        for step in 1..=5 {
            let (gen, _) = chain.save(&ck(step * 10)).unwrap();
            assert_eq!(gen, step as u64);
        }
        assert_eq!(chain.generations().unwrap(), vec![3, 4, 5]);
        let (gen, back) = chain.load_latest_valid().unwrap().unwrap();
        assert_eq!((gen, back.global_step), (5, 50));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_latest_falls_back_to_previous_generation() {
        let dir = tmp_dir("fallback");
        let chain = Chain::open(&dir, 3).unwrap();
        chain.save(&ck(10)).unwrap();
        chain.save(&ck(20)).unwrap();
        // flip one bit mid-payload in the newest generation
        let latest = dir.join("gen-000002.txck");
        let mut bytes = std::fs::read(&latest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x04;
        std::fs::write(&latest, &bytes).unwrap();
        let (gen, back) = chain.load_latest_valid().unwrap().unwrap();
        assert_eq!((gen, back.global_step), (1, 10));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn all_corrupt_is_an_error_not_a_fresh_start() {
        let dir = tmp_dir("allbad");
        let chain = Chain::open(&dir, 3).unwrap();
        chain.save(&ck(10)).unwrap();
        let p = dir.join("gen-000001.txck");
        std::fs::write(&p, b"TXCKgarbage").unwrap();
        assert!(chain.load_latest_valid().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reset_clears_generations_and_tmp_is_ignored() {
        let dir = tmp_dir("reset");
        let chain = Chain::open(&dir, 2).unwrap();
        chain.save(&ck(10)).unwrap();
        std::fs::write(dir.join("gen-000009.txck.tmp"), b"torn").unwrap();
        assert_eq!(chain.generations().unwrap(), vec![1]);
        chain.reset().unwrap();
        assert!(chain.load_latest_valid().unwrap().is_none());
        // next save sweeps the stale tmp
        chain.save(&ck(20)).unwrap();
        assert!(!dir.join("gen-000009.txck.tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
