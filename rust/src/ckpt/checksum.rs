//! CRC-32 (IEEE 802.3, reflected, polynomial 0xEDB88320) — the checksum
//! guarding the checkpoint container (DESIGN.md §16.2).
//!
//! Hand-rolled because the offline crate set has no `crc`; the standard
//! byte-at-a-time table method is plenty for checkpoint-sized payloads
//! (integrity detection, not a hot path). The table is built once at first
//! use.

use std::sync::OnceLock;

fn table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// Streaming CRC-32 over multiple byte slices.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let t = table();
        for &b in data {
            self.state = t[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
        }
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(data);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // the canonical check value for CRC-32/IEEE
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let mut c = Crc32::new();
        for chunk in data.chunks(7) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), crc32(data));
    }

    #[test]
    fn single_bit_flip_changes_checksum() {
        let mut data = vec![0u8; 1024];
        data[100] = 0x5A;
        let base = crc32(&data);
        for byte in [0usize, 100, 1023] {
            for bit in 0..8 {
                let mut corrupt = data.clone();
                corrupt[byte] ^= 1 << bit;
                assert_ne!(crc32(&corrupt), base, "flip at byte {byte} bit {bit} undetected");
            }
        }
    }
}
