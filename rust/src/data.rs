//! Synthetic data pipeline (S9): corpora, byte tokenizer, batcher.
//!
//! The paper's motivating use case is LLM pretraining; we have no corpus
//! on this image, so we synthesize deterministic corpora with enough
//! structure to (a) be learnable, (b) separate model capacities — the E3
//! progressive-vs-scratch experiment needs small models to plateau above
//! large ones (DESIGN.md §6 substitution table):
//!
//! * [`CorpusKind::MarkovText`] — text from a random order-2 character
//!   Markov chain over `a..z` + space. A 1-layer model can learn bigram
//!   stats; trigram structure rewards more capacity.
//! * [`CorpusKind::Copy`] — `<pattern>|<pattern>;` sequences; solvable
//!   only through attention (position-shifted copying).
//! * [`CorpusKind::Arithmetic`] — `a+b=c;` modular-sum strings; rewards
//!   MLP capacity.
//!
//! Tokenization is byte-level (vocab 256) so any corpus string is valid.

use crate::error::{Error, Result};
use crate::rng::Pcg32;

/// Which synthetic corpus to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusKind {
    MarkovText,
    Copy,
    Arithmetic,
}

impl CorpusKind {
    pub fn parse(name: &str) -> Result<CorpusKind> {
        match name {
            "markov" => Ok(CorpusKind::MarkovText),
            "copy" => Ok(CorpusKind::Copy),
            "arithmetic" => Ok(CorpusKind::Arithmetic),
            other => Err(Error::Cli(format!("unknown corpus '{other}' (markov|copy|arithmetic)"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CorpusKind::MarkovText => "markov",
            CorpusKind::Copy => "copy",
            CorpusKind::Arithmetic => "arithmetic",
        }
    }
}

/// Generate `len` bytes of the chosen corpus, deterministically from `seed`.
pub fn generate_corpus(kind: CorpusKind, len: usize, seed: u64) -> Vec<u8> {
    let mut rng = Pcg32::new(seed, 0xDA7A);
    match kind {
        CorpusKind::MarkovText => markov_text(len, &mut rng),
        CorpusKind::Copy => copy_task(len, &mut rng),
        CorpusKind::Arithmetic => arithmetic(len, &mut rng),
    }
}

const ALPHABET: &[u8] = b"abcdefghijklmnopqrstuvwxyz ";

fn markov_text(len: usize, rng: &mut Pcg32) -> Vec<u8> {
    let a = ALPHABET.len();
    // random sparse order-2 transition table: each (c1, c2) context gets a
    // handful of plausible successors with random weights.
    let mut table = vec![Vec::new(); a * a];
    for ctx in table.iter_mut() {
        let succ = 2 + rng.below(3);
        for _ in 0..succ {
            ctx.push((rng.below(a), 1.0 + rng.uniform() * 4.0));
        }
    }
    let mut out = Vec::with_capacity(len);
    let (mut c1, mut c2) = (rng.below(a), rng.below(a));
    for _ in 0..len {
        let ctx = &table[c1 * a + c2];
        let weights: Vec<f64> = ctx.iter().map(|&(_, w)| w).collect();
        let next = ctx[rng.weighted(&weights)].0;
        out.push(ALPHABET[next]);
        c1 = c2;
        c2 = next;
    }
    out
}

fn copy_task(len: usize, rng: &mut Pcg32) -> Vec<u8> {
    // "<pattern>|<pattern>;" with pattern length 3..=8 over a..z
    let mut out = Vec::with_capacity(len + 20);
    while out.len() < len {
        let plen = 3 + rng.below(6);
        let pattern: Vec<u8> = (0..plen).map(|_| ALPHABET[rng.below(26)]).collect();
        out.extend_from_slice(&pattern);
        out.push(b'|');
        out.extend_from_slice(&pattern);
        out.push(b';');
    }
    out.truncate(len);
    out
}

fn arithmetic(len: usize, rng: &mut Pcg32) -> Vec<u8> {
    // "a+b=c;" with c = (a+b) mod 100, all two-digit zero-padded
    let mut out = Vec::with_capacity(len + 10);
    while out.len() < len {
        let a = rng.below(100);
        let b = rng.below(100);
        let c = (a + b) % 100;
        out.extend_from_slice(format!("{a:02}+{b:02}={c:02};").as_bytes());
    }
    out.truncate(len);
    out
}

// ---------------------------------------------------------------------------
// Tokenizer
// ---------------------------------------------------------------------------

/// Byte-level tokenizer: token id == byte value (vocab 256). Trivial but
/// explicit, so vocab bounds are checked in one place.
pub struct ByteTokenizer {
    vocab: usize,
}

impl ByteTokenizer {
    pub fn new(vocab: usize) -> Result<ByteTokenizer> {
        if vocab == 0 || vocab > 256 {
            return Err(Error::Config(format!("byte tokenizer vocab must be in [1,256], got {vocab}")));
        }
        Ok(ByteTokenizer { vocab })
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Encode bytes to token ids; bytes >= vocab are folded by modulo (our
    /// corpora are ASCII so vocab >= 128 never folds).
    pub fn encode(&self, bytes: &[u8]) -> Vec<u32> {
        bytes.iter().map(|&b| (b as usize % self.vocab) as u32).collect()
    }

    /// Decode ids to bytes (inverse of encode for unfolded tokens).
    pub fn decode(&self, tokens: &[u32]) -> Vec<u8> {
        tokens.iter().map(|&t| (t % 256) as u8).collect()
    }
}

// ---------------------------------------------------------------------------
// Batcher
// ---------------------------------------------------------------------------

/// One training batch: `tokens[b][t]` predicts `targets[b][t]`.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<Vec<u32>>,
    pub targets: Vec<Vec<u32>>,
}

impl Batch {
    /// Uniform-random token/target rows for a config — the shared
    /// test/bench batch builder (training data comes from [`Batcher`]).
    pub fn random(cfg: &crate::config::ModelConfig, rows: usize, seed: u64) -> Batch {
        let mut rng = Pcg32::seeded(seed);
        let row = |rng: &mut Pcg32| (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u32).collect();
        Batch {
            tokens: (0..rows).map(|_| row(&mut rng)).collect(),
            targets: (0..rows).map(|_| row(&mut rng)).collect(),
        }
    }
}

/// Samples random `(seq+1)`-windows from a token stream; the window's first
/// `seq` tokens are inputs and the 1-shifted window is the target.
/// `Clone` duplicates the stream *and* the draw RNG: a clone yields the
/// exact batch sequence the original would — how greedy policy probes
/// train candidate branches on the very data the live run consumes next.
#[derive(Clone)]
pub struct Batcher {
    stream: Vec<u32>,
    seq: usize,
    batch: usize,
    rng: Pcg32,
}

impl Batcher {
    pub fn new(stream: Vec<u32>, seq: usize, batch: usize, seed: u64) -> Result<Batcher> {
        if stream.len() < seq + 1 {
            return Err(Error::Config(format!(
                "stream of {} tokens too short for seq {}",
                stream.len(),
                seq
            )));
        }
        if batch == 0 || seq == 0 {
            return Err(Error::Config("batch and seq must be positive".into()));
        }
        Ok(Batcher { stream, seq, batch, rng: Pcg32::new(seed, 0xBA7C) })
    }

    /// Convenience: synthesize a corpus and wrap it.
    pub fn from_corpus(
        kind: CorpusKind,
        corpus_len: usize,
        vocab: usize,
        seq: usize,
        batch: usize,
        seed: u64,
    ) -> Result<Batcher> {
        let tok = ByteTokenizer::new(vocab)?;
        let stream = tok.encode(&generate_corpus(kind, corpus_len, seed));
        Batcher::new(stream, seq, batch, seed ^ 0x5EED)
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Next random batch (deterministic from the construction seed).
    pub fn next(&mut self) -> Batch {
        let max_start = self.stream.len() - self.seq - 1;
        let mut tokens = Vec::with_capacity(self.batch);
        let mut targets = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let start = self.rng.below(max_start + 1);
            tokens.push(self.stream[start..start + self.seq].to_vec());
            targets.push(self.stream[start + 1..start + self.seq + 1].to_vec());
        }
        Batch { tokens, targets }
    }

    /// Snapshot the draw RNG for checkpointing (the stream itself is
    /// reconstructed deterministically from the corpus parameters at
    /// resume, so the cursor state *is* the whole mutable state).
    pub fn rng_parts(&self) -> (u64, u64, Option<f64>) {
        self.rng.to_parts()
    }

    /// Restore the draw RNG from [`Batcher::rng_parts`] output: the next
    /// [`Batcher::next`] yields exactly the batch the snapshotted batcher
    /// would have yielded.
    pub fn restore_rng(&mut self, state: u64, inc: u64, spare_normal: Option<f64>) {
        self.rng = Pcg32::from_parts(state, inc, spare_normal);
    }

    /// A held-out probe batch drawn from an independent stream position
    /// generator (stable across calls — used for preservation checks and
    /// eval loss so train/probe randomness never interleave).
    pub fn probe(&self, seed: u64) -> Batch {
        let mut rng = Pcg32::new(seed, 0x9B0E);
        let max_start = self.stream.len() - self.seq - 1;
        let mut tokens = Vec::with_capacity(self.batch);
        let mut targets = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let start = rng.below(max_start + 1);
            tokens.push(self.stream[start..start + self.seq].to_vec());
            targets.push(self.stream[start + 1..start + self.seq + 1].to_vec());
        }
        Batch { tokens, targets }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpora_are_deterministic() {
        for kind in [CorpusKind::MarkovText, CorpusKind::Copy, CorpusKind::Arithmetic] {
            let a = generate_corpus(kind, 1000, 7);
            let b = generate_corpus(kind, 1000, 7);
            let c = generate_corpus(kind, 1000, 8);
            assert_eq!(a, b, "{kind:?}");
            assert_ne!(a, c, "{kind:?} must vary with seed");
            assert_eq!(a.len(), 1000);
        }
    }

    #[test]
    fn markov_uses_alphabet_only() {
        let text = generate_corpus(CorpusKind::MarkovText, 5000, 1);
        assert!(text.iter().all(|b| ALPHABET.contains(b)));
        // all three common letters should appear in 5k chars
        let distinct: std::collections::HashSet<u8> = text.iter().copied().collect();
        assert!(distinct.len() > 5, "degenerate chain: {} symbols", distinct.len());
    }

    #[test]
    fn copy_task_repeats_patterns() {
        let text = generate_corpus(CorpusKind::Copy, 2000, 2);
        let s = String::from_utf8(text).unwrap();
        // every complete record "<p>|<p>;" satisfies the copy invariant
        let mut checked = 0;
        for record in s.split(';') {
            if let Some((a, b)) = record.split_once('|') {
                if !a.is_empty() && a.len() == b.len() {
                    assert_eq!(a, b, "copy violated in {record:?}");
                    checked += 1;
                }
            }
        }
        assert!(checked > 10, "too few complete records: {checked}");
    }

    #[test]
    fn arithmetic_sums_are_correct_mod_100() {
        let text = generate_corpus(CorpusKind::Arithmetic, 2000, 3);
        let s = String::from_utf8(text).unwrap();
        let mut checked = 0;
        for record in s.split(';') {
            if record.len() == 8 {
                // "aa+bb=cc"
                let a: usize = record[0..2].parse().unwrap();
                let b: usize = record[3..5].parse().unwrap();
                let c: usize = record[6..8].parse().unwrap();
                assert_eq!((a + b) % 100, c, "{record}");
                checked += 1;
            }
        }
        assert!(checked > 10);
    }

    #[test]
    fn corpus_kind_parse_roundtrip() {
        for kind in [CorpusKind::MarkovText, CorpusKind::Copy, CorpusKind::Arithmetic] {
            assert_eq!(CorpusKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(CorpusKind::parse("wikipedia").is_err());
    }

    #[test]
    fn tokenizer_bounds_and_roundtrip() {
        assert!(ByteTokenizer::new(0).is_err());
        assert!(ByteTokenizer::new(257).is_err());
        let tok = ByteTokenizer::new(256).unwrap();
        let bytes = b"hello world".to_vec();
        let ids = tok.encode(&bytes);
        assert!(ids.iter().all(|&t| t < 256));
        assert_eq!(tok.decode(&ids), bytes);
    }

    #[test]
    fn tokenizer_folds_to_vocab() {
        let tok = ByteTokenizer::new(128).unwrap();
        let ids = tok.encode(&[200u8, 127, 0]);
        assert!(ids.iter().all(|&t| t < 128));
    }

    #[test]
    fn batcher_shapes_and_shift() {
        let stream: Vec<u32> = (0..100).collect();
        let mut b = Batcher::new(stream, 8, 4, 1).unwrap();
        let batch = b.next();
        assert_eq!(batch.tokens.len(), 4);
        assert_eq!(batch.tokens[0].len(), 8);
        for (toks, tgts) in batch.tokens.iter().zip(&batch.targets) {
            for i in 0..8 {
                assert_eq!(tgts[i], toks[i] + 1, "targets must be the 1-shifted window");
            }
        }
    }

    #[test]
    fn batcher_deterministic_and_probe_stable() {
        let stream: Vec<u32> = (0..1000).map(|i| i % 50).collect();
        let mut a = Batcher::new(stream.clone(), 16, 2, 9).unwrap();
        let mut b = Batcher::new(stream.clone(), 16, 2, 9).unwrap();
        assert_eq!(a.next().tokens, b.next().tokens);
        // probe is stable no matter how much training data was consumed
        let p1 = a.probe(5);
        let _ = a.next();
        let _ = a.next();
        let p2 = a.probe(5);
        assert_eq!(p1.tokens, p2.tokens);
        // probe with a different seed differs
        assert_ne!(p1.tokens, a.probe(6).tokens);
    }

    #[test]
    fn batcher_rng_round_trip_resumes_batch_stream() {
        let stream: Vec<u32> = (0..1000).map(|i| i % 50).collect();
        let mut live = Batcher::new(stream.clone(), 16, 2, 9).unwrap();
        let _ = live.next();
        let _ = live.next();
        let (state, inc, spare) = live.rng_parts();
        let mut restored = Batcher::new(stream, 16, 2, 9).unwrap();
        restored.restore_rng(state, inc, spare);
        for _ in 0..8 {
            assert_eq!(live.next().tokens, restored.next().tokens);
        }
    }

    #[test]
    fn batcher_rejects_short_streams() {
        assert!(Batcher::new(vec![1, 2, 3], 8, 1, 0).is_err());
        assert!(Batcher::new((0..100).collect(), 0, 1, 0).is_err());
        assert!(Batcher::new((0..100).collect(), 8, 0, 0).is_err());
    }

    #[test]
    fn from_corpus_respects_vocab() {
        let mut b = Batcher::from_corpus(CorpusKind::MarkovText, 5000, 256, 32, 4, 11).unwrap();
        let batch = b.next();
        assert!(batch.tokens.iter().flatten().all(|&t| t < 256));
    }
}
