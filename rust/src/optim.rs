//! Optimizers with expansion-aware state surgery (S8).
//!
//! The PJRT `step` artifacts return *gradients*; the optimizer itself runs
//! here so that its state lives next to the parameters it tracks — at every
//! expansion boundary the coordinator transforms parameters *and* moments
//! through one plan ([`crate::expand::ExpansionPlan::apply_train`]; the
//! moment surgery itself is the optimizer's `Expandable` impl in
//! [`crate::expand::plan`]).
//!
//! ## Moment surgery
//!
//! Adam's moments are per-scalar statistics, so they undergo the *same
//! geometric* surgery as their parameter (concat in the same places), with
//! new slices **zero** (fresh capacity has no gradient history). The two
//! reparametrizations the paper introduces scale kept parameters by a
//! factor `c` (Eq. 19: W^K by `sqrt(k̂/k)`; Eq. 24: norm gains by
//! `sqrt(h/ĥ)`); under `ŵ = c·w` gradients scale as `∂L/∂ŵ = (1/c)·∂L/∂w`,
//! so the first moment is rescaled by `c^-1` and the second by `c^-2` —
//! exactly what `ExpandOptions::for_moments(-1.0 / -2.0)` implements.

use crate::config::{OptimKind, TrainConfig};
use crate::error::{Error, Result};
use crate::params::ParamStore;
use crate::tensor::Tensor;

/// Optimizer state (moments stored as ParamStores so they share the
/// canonical layout and the expansion machinery).
#[derive(Clone, Debug)]
pub enum Optimizer {
    Sgd {
        lr: f32,
    },
    Adam {
        lr: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
        /// Update count (bias correction).
        t: u64,
        m: ParamStore,
        v: ParamStore,
    },
}

impl Optimizer {
    /// Build from a training config, with moments shaped like `params`.
    pub fn new(cfg: &TrainConfig, params: &ParamStore) -> Optimizer {
        match cfg.optimizer {
            OptimKind::Sgd => Optimizer::Sgd { lr: cfg.lr },
            OptimKind::Adam => Optimizer::Adam {
                lr: cfg.lr,
                beta1: cfg.beta1,
                beta2: cfg.beta2,
                eps: cfg.adam_eps,
                t: 0,
                m: ParamStore::zeros(params.config()),
                v: ParamStore::zeros(params.config()),
            },
        }
    }

    /// Human-readable name (logs).
    pub fn name(&self) -> &'static str {
        match self {
            Optimizer::Sgd { .. } => "sgd",
            Optimizer::Adam { .. } => "adam",
        }
    }

    /// In-place parameter update from canonical-order gradients.
    pub fn step(&mut self, params: &mut ParamStore, grads: &[Tensor]) -> Result<()> {
        if grads.len() != params.len() {
            return Err(Error::Train(format!(
                "optimizer step: {} grads for {} params",
                grads.len(),
                params.len()
            )));
        }
        match self {
            Optimizer::Sgd { lr } => {
                for (p, g) in params.tensors_mut().iter_mut().zip(grads) {
                    if p.shape() != g.shape() {
                        return Err(Error::Train(format!(
                            "sgd: grad shape {:?} vs param {:?}",
                            g.shape(),
                            p.shape()
                        )));
                    }
                    for (pv, gv) in p.data_mut().iter_mut().zip(g.data()) {
                        *pv -= *lr * gv;
                    }
                }
            }
            Optimizer::Adam { lr, beta1, beta2, eps, t, m, v } => {
                *t += 1;
                let bc1 = 1.0 - beta1.powi(*t as i32);
                let bc2 = 1.0 - beta2.powi(*t as i32);
                for ((p, g), (mt, vt)) in params
                    .tensors_mut()
                    .iter_mut()
                    .zip(grads)
                    .zip(m.tensors_mut().iter_mut().zip(v.tensors_mut().iter_mut()))
                {
                    if p.shape() != g.shape() {
                        return Err(Error::Train(format!(
                            "adam: grad shape {:?} vs param {:?}",
                            g.shape(),
                            p.shape()
                        )));
                    }
                    let (b1, b2) = (*beta1, *beta2);
                    for i in 0..p.numel() {
                        let gv = g.data()[i];
                        let mv = b1 * mt.data()[i] + (1.0 - b1) * gv;
                        let vv = b2 * vt.data()[i] + (1.0 - b2) * gv * gv;
                        mt.data_mut()[i] = mv;
                        vt.data_mut()[i] = vv;
                        let m_hat = mv / bc1;
                        let v_hat = vv / bc2;
                        p.data_mut()[i] -= *lr * m_hat / (v_hat.sqrt() + *eps);
                    }
                }
            }
        }
        Ok(())
    }

    /// Expanded-state invariant check: moments must mirror the param layout.
    pub fn validate_against(&self, params: &ParamStore) -> Result<()> {
        if let Optimizer::Adam { m, v, .. } = self {
            if m.config() != params.config() || v.config() != params.config() {
                return Err(Error::Train(format!(
                    "optimizer state config {:?} does not match params {:?}",
                    m.config(),
                    params.config()
                )));
            }
        }
        Ok(())
    }
}

/// Global-norm gradient clipping (in place). Returns the pre-clip norm.
pub fn clip_global_norm(grads: &mut [Tensor], max_norm: f32) -> f32 {
    let mut sq = 0.0f64;
    for g in grads.iter() {
        for x in g.data() {
            sq += (*x as f64) * (*x as f64);
        }
    }
    let norm = sq.sqrt() as f32;
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            g.scale(scale);
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GrowthOp, LayerPosition, ModelConfig};
    use crate::expand::{Expandable, ExpansionPlan};
    use crate::rng::Pcg32;

    /// Expand params + moments through the plan seam (the only entry).
    fn expand_both(
        params: &ParamStore,
        opt: &mut Optimizer,
        ops: &[GrowthOp],
        seed: u64,
    ) -> ParamStore {
        let plan = ExpansionPlan::new(params.config(), ops.to_vec()).unwrap();
        let expanded = plan
            .materialize(params, &Default::default(), &mut Pcg32::seeded(seed))
            .unwrap();
        opt.apply_plan(&plan, &Default::default(), &mut Pcg32::seeded(seed)).unwrap();
        expanded
    }

    fn cfg() -> ModelConfig {
        ModelConfig { layers: 1, hidden: 8, heads: 2, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 }
    }

    fn train_cfg(kind: OptimKind, lr: f32) -> TrainConfig {
        TrainConfig { optimizer: kind, lr, ..Default::default() }
    }

    fn quadratic_grads(params: &ParamStore) -> Vec<Tensor> {
        // grad of 0.5*||p||^2 is p itself: descending must shrink the norm
        params.tensors().to_vec()
    }

    fn norm(params: &ParamStore) -> f64 {
        params.tensors().iter().flat_map(|t| t.data()).map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt()
    }

    #[test]
    fn sgd_descends_quadratic() {
        let mut rng = Pcg32::seeded(1);
        let mut params = ParamStore::init(&cfg(), &mut rng, 0.1);
        let mut opt = Optimizer::new(&train_cfg(OptimKind::Sgd, 0.1), &params);
        let before = norm(&params);
        for _ in 0..10 {
            let grads = quadratic_grads(&params);
            opt.step(&mut params, &grads).unwrap();
        }
        assert!(norm(&params) < 0.5 * before);
    }

    #[test]
    fn sgd_update_is_exact() {
        let mut params = ParamStore::zeros(&cfg());
        params.get_mut("w_out").unwrap().data_mut()[0] = 1.0;
        let mut grads: Vec<Tensor> = params.tensors().iter().map(|t| Tensor::zeros(t.shape())).collect();
        let w_out_idx = params.specs().iter().position(|s| s.name == "w_out").unwrap();
        grads[w_out_idx].data_mut()[0] = 2.0;
        let mut opt = Optimizer::new(&train_cfg(OptimKind::Sgd, 0.25), &params);
        opt.step(&mut params, &grads).unwrap();
        assert!((params.get("w_out").unwrap().data()[0] - 0.5).abs() < 1e-7);
    }

    #[test]
    fn adam_descends_quadratic() {
        let mut rng = Pcg32::seeded(2);
        let mut params = ParamStore::init(&cfg(), &mut rng, 0.1);
        let mut opt = Optimizer::new(&train_cfg(OptimKind::Adam, 0.01), &params);
        let before = norm(&params);
        for _ in 0..50 {
            let grads = quadratic_grads(&params);
            opt.step(&mut params, &grads).unwrap();
        }
        assert!(norm(&params) < before);
    }

    #[test]
    fn adam_first_step_magnitude_is_lr() {
        // with bias correction, |Δp| of the very first Adam step ≈ lr
        let mut params = ParamStore::zeros(&cfg());
        params.get_mut("embed").unwrap().data_mut()[0] = 5.0;
        let mut grads: Vec<Tensor> = params.tensors().iter().map(|t| Tensor::zeros(t.shape())).collect();
        grads[0].data_mut()[0] = 3.0; // embed is index 0
        let mut opt = Optimizer::new(&train_cfg(OptimKind::Adam, 0.01), &params);
        opt.step(&mut params, &grads).unwrap();
        let delta = 5.0 - params.get("embed").unwrap().data()[0];
        assert!((delta - 0.01).abs() < 1e-4, "delta {delta}");
    }

    #[test]
    fn step_rejects_mismatched_grads() {
        let mut params = ParamStore::zeros(&cfg());
        let mut opt = Optimizer::new(&train_cfg(OptimKind::Adam, 0.01), &params);
        let grads = vec![Tensor::zeros(&[1])];
        assert!(opt.step(&mut params, &grads).is_err());
    }

    #[test]
    fn adam_moment_surgery_matches_param_layout() {
        let mut rng = Pcg32::seeded(3);
        let mut params = ParamStore::init(&cfg(), &mut rng, 0.1);
        let mut opt = Optimizer::new(&train_cfg(OptimKind::Adam, 0.01), &params);
        // accumulate some real moments
        for _ in 0..3 {
            let grads = quadratic_grads(&params);
            opt.step(&mut params, &grads).unwrap();
        }
        let ops = vec![
            GrowthOp::Mlp { p: 32 },
            GrowthOp::HeadsAdd { count: 1 },
            GrowthOp::AttnExpand { k: 8 },
            GrowthOp::Hidden { h: 12 },
            GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top },
        ];
        let expanded = expand_both(&params, &mut opt, &ops, 4);
        opt.validate_against(&expanded).unwrap();
        // and stepping still works post-surgery
        let mut p2 = expanded.clone();
        let grads = quadratic_grads(&p2);
        opt.step(&mut p2, &grads).unwrap();
    }

    #[test]
    fn moment_surgery_zeroes_new_and_rescales_kept() {
        let mut rng = Pcg32::seeded(5);
        let mut params = ParamStore::init(&cfg(), &mut rng, 0.1);
        let mut opt = Optimizer::new(&train_cfg(OptimKind::Adam, 0.01), &params);
        let grads = quadratic_grads(&params);
        opt.step(&mut params, &grads).unwrap();
        let (m_before, v_before) = match &opt {
            Optimizer::Adam { m, v, .. } => (m.clone(), v.clone()),
            _ => unreachable!(),
        };
        let old_k = cfg().k;
        let new_k = 2 * old_k;
        let ops = vec![GrowthOp::AttnExpand { k: new_k }];
        expand_both(&params, &mut opt, &ops, 4);
        let (m_after, v_after) = match &opt {
            Optimizer::Adam { m, v, .. } => (m.clone(), v.clone()),
            _ => unreachable!(),
        };
        let c = ((new_k as f32) / (old_k as f32)).sqrt();
        // kept W^K slice: m scaled by 1/c, v by 1/c^2
        let m_old = m_before.get("layer_0.head_0.wk").unwrap();
        let m_new = m_after.get("layer_0.head_0.wk").unwrap();
        let kept = m_new.slice_cols(0, old_k).unwrap();
        let mut want = m_old.clone();
        want.scale(1.0 / c);
        assert!(kept.max_abs_diff(&want).unwrap() < 1e-6);
        // new columns zero
        assert_eq!(m_new.slice_cols(old_k, new_k).unwrap().max_abs(), 0.0);
        let v_old = v_before.get("layer_0.head_0.wk").unwrap();
        let v_new = v_after.get("layer_0.head_0.wk").unwrap();
        let mut want_v = v_old.clone();
        want_v.scale(1.0 / (c * c));
        assert!(v_new.slice_cols(0, old_k).unwrap().max_abs_diff(&want_v).unwrap() < 1e-6);
        // W^Q moments (unconstrained new cols) are zero too — Init::Zeros
        let mq = m_after.get("layer_0.head_0.wq").unwrap();
        assert_eq!(mq.slice_cols(old_k, new_k).unwrap().max_abs(), 0.0);
    }

    #[test]
    fn adam_moments_stay_in_canonical_order_after_each_op() {
        // after every one of the six ops, each moment tensor must sit at
        // the same canonical index as its parameter, with the same shape —
        // the invariant Optimizer::step's positional zip depends on
        let ops: [GrowthOp; 6] = [
            GrowthOp::Mlp { p: 32 },
            GrowthOp::HeadsAdd { count: 1 },
            GrowthOp::HeadsExpand { v: 8 },
            GrowthOp::AttnExpand { k: 8 },
            GrowthOp::Hidden { h: 12 },
            GrowthOp::LayersAdd { count: 1, position: LayerPosition::Bottom },
        ];
        for op in ops {
            let mut rng = Pcg32::seeded(7);
            let mut params = ParamStore::init(&cfg(), &mut rng, 0.1);
            let mut opt = Optimizer::new(&train_cfg(OptimKind::Adam, 0.01), &params);
            let grads = quadratic_grads(&params);
            opt.step(&mut params, &grads).unwrap();

            let expanded = expand_both(&params, &mut opt, std::slice::from_ref(&op), 8);
            opt.validate_against(&expanded).unwrap();
            let (m, v) = match &opt {
                Optimizer::Adam { m, v, .. } => (m, v),
                _ => unreachable!(),
            };
            for ((spec, p), ((m_spec, mt), (v_spec, vt))) in
                expanded.iter().zip(m.iter().zip(v.iter()))
            {
                assert_eq!(spec.name, m_spec.name, "{op:?}: m order diverged");
                assert_eq!(spec.name, v_spec.name, "{op:?}: v order diverged");
                assert_eq!(p.shape(), mt.shape(), "{op:?}: {} m shape", spec.name);
                assert_eq!(p.shape(), vt.shape(), "{op:?}: {} v shape", spec.name);
                assert!(mt.all_finite() && vt.all_finite(), "{op:?}: {}", spec.name);
            }
        }
    }

    #[test]
    fn training_resumes_without_loss_spike_after_each_op() {
        // the satellite acceptance: warm up Adam on the native backend,
        // expand params + moments with each of the six ops, keep training —
        // the first post-boundary loss must sit at the pre-boundary level
        // (preservation) and continued steps must not blow up
        use crate::autodiff::loss_and_grads;
        use crate::data::Batcher;

        let base_cfg = cfg();
        let tcfg = train_cfg(OptimKind::Adam, 1e-3);
        let mut batcher = Batcher::from_corpus(
            crate::data::CorpusKind::MarkovText,
            20_000,
            base_cfg.vocab,
            base_cfg.seq,
            4,
            11,
        )
        .unwrap();
        let mut rng = Pcg32::seeded(9);
        let mut params = ParamStore::init(&base_cfg, &mut rng, 0.05);
        let mut opt = Optimizer::new(&tcfg, &params);
        let mut pre_loss = f32::NAN;
        for _ in 0..5 {
            let batch = batcher.next();
            let (loss, grads) = loss_and_grads(&base_cfg, &params, &batch).unwrap();
            pre_loss = loss;
            opt.step(&mut params, &grads).unwrap();
        }
        let probe = batcher.probe(13);
        let (probe_pre, _) = loss_and_grads(&base_cfg, &params, &probe).unwrap();

        let ops: [GrowthOp; 6] = [
            GrowthOp::Mlp { p: 32 },
            GrowthOp::HeadsAdd { count: 1 },
            GrowthOp::HeadsExpand { v: 8 },
            GrowthOp::AttnExpand { k: 8 },
            GrowthOp::Hidden { h: 12 },
            GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top },
        ];
        for op in ops {
            let mut opt2 = opt.clone();
            let expanded = expand_both(&params, &mut opt2, std::slice::from_ref(&op), 10);
            opt2.validate_against(&expanded).unwrap();
            let new_cfg = *expanded.config();

            // preservation: probe loss unchanged through the boundary
            let (probe_post, _) = loss_and_grads(&new_cfg, &expanded, &probe).unwrap();
            assert!(
                (probe_post - probe_pre).abs() <= 1e-4,
                "{op:?}: probe loss moved {probe_pre} -> {probe_post}"
            );

            // resume: 3 more steps; first post-boundary training loss must
            // not spike above the pre-boundary level + step noise
            let mut p2 = expanded;
            let mut first_post = f32::NAN;
            for step in 0..3 {
                let batch = batcher.next();
                let (loss, grads) = loss_and_grads(&new_cfg, &p2, &batch).unwrap();
                if step == 0 {
                    first_post = loss;
                }
                assert!(loss.is_finite(), "{op:?}: non-finite loss at resume step {step}");
                opt2.step(&mut p2, &grads).unwrap();
            }
            assert!(
                first_post <= pre_loss + 0.5,
                "{op:?}: post-boundary loss spike {pre_loss} -> {first_post}"
            );
            assert!(p2.all_finite(), "{op:?}: params went non-finite after resume");
        }
    }

    #[test]
    fn sgd_expand_is_noop() {
        let mut opt = Optimizer::Sgd { lr: 0.1 };
        let plan = ExpansionPlan::new(&cfg(), vec![GrowthOp::Mlp { p: 32 }]).unwrap();
        opt.apply_plan(&plan, &Default::default(), &mut Pcg32::seeded(0)).unwrap();
        let params = ParamStore::zeros(&cfg());
        opt.validate_against(&params).unwrap();
    }

    #[test]
    fn clip_global_norm_behaviour() {
        let mut grads = vec![Tensor::full(&[4], 3.0)]; // norm 6
        let norm = clip_global_norm(&mut grads, 2.0);
        assert!((norm - 6.0).abs() < 1e-5);
        let new_sq: f32 = grads[0].data().iter().map(|x| x * x).sum();
        assert!((new_sq.sqrt() - 2.0).abs() < 1e-5);
        // under the threshold: untouched
        let mut grads = vec![Tensor::full(&[4], 0.5)]; // norm 1
        let norm = clip_global_norm(&mut grads, 2.0);
        assert!((norm - 1.0).abs() < 1e-6);
        assert_eq!(grads[0].data(), &[0.5; 4]);
        // zero grads: no NaN
        let mut grads = vec![Tensor::zeros(&[4])];
        assert_eq!(clip_global_norm(&mut grads, 1.0), 0.0);
        assert_eq!(grads[0].data(), &[0.0; 4]);
    }
}
