//! Command-line argument parsing (S11; no `clap` offline).
//!
//! Syntax: `texpand <subcommand> [positional]... [--flag value]... [--switch]...`.
//! [`Args`] splits the raw argv into a subcommand, positional operands,
//! `--key value` flags and bare switches, with typed accessors and
//! unknown-flag/-positional detection so typos fail instead of being
//! silently ignored. Positionals belong *before* the flags: a bare token
//! right after `--flag` is that flag's value, not an operand.

use std::collections::{HashMap, HashSet};

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Clone, Debug)]
pub struct Args {
    pub subcommand: Option<String>,
    positionals: Vec<String>,
    flags: HashMap<String, String>,
    switches: HashSet<String>,
    consumed: std::cell::RefCell<HashSet<String>>,
    consumed_positionals: std::cell::RefCell<HashSet<usize>>,
}

impl Args {
    /// Parse from raw argv (without the binary name). Flags take exactly
    /// one value; a flag followed by another `--flag` or end of input is a
    /// switch.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let subcommand = match it.peek() {
            Some(first) if !first.starts_with("--") => Some(it.next().unwrap()),
            _ => None,
        };
        let mut positionals = Vec::new();
        let mut flags = HashMap::new();
        let mut switches = HashSet::new();
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                // collected, not rejected: subcommands that take operands
                // claim them via `positional`; `reject_unknown` catches
                // the rest (so `texpand train oops` still fails)
                positionals.push(arg);
                continue;
            };
            if name.is_empty() {
                return Err(Error::Cli("empty flag '--'".into()));
            }
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
                continue;
            }
            match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    flags.insert(name.to_string(), it.next().unwrap());
                }
                _ => {
                    switches.insert(name.to_string());
                }
            }
        }
        Ok(Args {
            subcommand,
            positionals,
            flags,
            switches,
            consumed: Default::default(),
            consumed_positionals: Default::default(),
        })
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// The `i`-th positional operand after the subcommand, if present.
    pub fn positional(&self, i: usize) -> Option<String> {
        self.consumed_positionals.borrow_mut().insert(i);
        self.positionals.get(i).cloned()
    }

    /// Required positional operand; `what` names it in the error
    /// (e.g. "RUN").
    pub fn require_positional(&self, i: usize, what: &str) -> Result<String> {
        self.positional(i)
            .ok_or_else(|| Error::Cli(format!("missing required {what} argument")))
    }

    /// String flag.
    pub fn get(&self, name: &str) -> Option<String> {
        self.consumed.borrow_mut().insert(name.to_string());
        self.flags.get(name).cloned()
    }

    /// String flag with default.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or_else(|| default.to_string())
    }

    /// Required string flag.
    pub fn require(&self, name: &str) -> Result<String> {
        self.get(name).ok_or_else(|| Error::Cli(format!("missing required flag --{name}")))
    }

    /// Enumerated flag: the value must be one of `choices` (error lists
    /// them), `None` when absent. Used for `--backend`, `--policy`,
    /// `--optimizer` so a typo'd mode reports the valid set instead of
    /// surfacing as a downstream failure.
    pub fn get_choice(&self, name: &str, choices: &[&str]) -> Result<Option<String>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) if choices.contains(&v.as_str()) => Ok(Some(v)),
            Some(v) => Err(Error::Cli(format!(
                "--{name} expects one of {}, got '{v}'",
                choices.join("|")
            ))),
        }
    }

    /// Typed numeric flags.
    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        self.get(name)
            .map(|v| v.parse::<usize>().map_err(|_| Error::Cli(format!("--{name} expects an integer, got '{v}'"))))
            .transpose()
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        self.get(name)
            .map(|v| v.parse::<f64>().map_err(|_| Error::Cli(format!("--{name} expects a number, got '{v}'"))))
            .transpose()
    }

    /// f32 convenience over [`Args::get_f64`] (sampler knobs etc.).
    pub fn get_f32(&self, name: &str) -> Result<Option<f32>> {
        Ok(self.get_f64(name)?.map(|v| v as f32))
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>> {
        self.get(name)
            .map(|v| v.parse::<u64>().map_err(|_| Error::Cli(format!("--{name} expects an integer, got '{v}'"))))
            .transpose()
    }

    /// Boolean switch.
    pub fn has(&self, name: &str) -> bool {
        self.consumed.borrow_mut().insert(name.to_string());
        self.switches.contains(name)
    }

    /// After consuming all known flags and positionals, reject anything
    /// left over (typo'd flags, stray operands).
    pub fn reject_unknown(&self) -> Result<()> {
        let pos_consumed = self.consumed_positionals.borrow();
        if let Some(stray) = self
            .positionals
            .iter()
            .enumerate()
            .find(|(i, _)| !pos_consumed.contains(i))
            .map(|(_, s)| s)
        {
            return Err(Error::Cli(format!("unexpected positional argument '{stray}'")));
        }
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !consumed.contains(*k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            let mut names: Vec<String> = unknown.iter().map(|s| format!("--{s}")).collect();
            names.sort();
            Err(Error::Cli(format!("unknown flags: {}", names.join(", "))))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = args("train --schedule configs/g.json --steps-scale 0.5 --quiet");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("schedule").unwrap(), "configs/g.json");
        assert_eq!(a.get_f64("steps-scale").unwrap(), Some(0.5));
        assert!(a.has("quiet"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = args("train --lr=0.001 --name=run-1");
        assert_eq!(a.get("lr").unwrap(), "0.001");
        assert_eq!(a.get("name").unwrap(), "run-1");
    }

    #[test]
    fn trailing_flag_is_switch() {
        let a = args("verify --no-save");
        assert!(a.has("no-save"));
    }

    #[test]
    fn no_subcommand() {
        let a = args("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.has("help"));
    }

    #[test]
    fn typed_accessors_validate() {
        let a = args("x --n 5 --f 1.5 --bad abc");
        assert_eq!(a.get_usize("n").unwrap(), Some(5));
        assert_eq!(a.get_f64("f").unwrap(), Some(1.5));
        assert_eq!(a.get_f32("f").unwrap(), Some(1.5));
        assert!(a.get_usize("bad").is_err());
        assert!(a.get_f32("bad").is_err());
        assert_eq!(a.get_u64("missing").unwrap(), None);
        assert_eq!(a.get_f32("missing").unwrap(), None);
    }

    #[test]
    fn get_choice_validates_against_set() {
        let a = args("train --policy plateau --backend tpu-v9");
        assert_eq!(a.get_choice("policy", &["fixed", "plateau", "greedy"]).unwrap().as_deref(), Some("plateau"));
        let err = a.get_choice("backend", &["native", "pjrt"]).unwrap_err().to_string();
        assert!(err.contains("native|pjrt") && err.contains("tpu-v9"), "{err}");
        assert_eq!(a.get_choice("missing", &["x"]).unwrap(), None);
        // choice lookups count as consumption for reject_unknown
        a.reject_unknown().unwrap();
    }

    #[test]
    fn require_reports_flag_name() {
        let a = args("x");
        let err = a.require("schedule").unwrap_err().to_string();
        assert!(err.contains("--schedule"), "{err}");
    }

    #[test]
    fn rejects_positional_noise() {
        // parse collects the operand; reject_unknown (which every
        // subcommand calls) refuses it if nothing claimed it
        let a = args("train oops --schedule s.json");
        let _ = a.get("schedule");
        let err = a.reject_unknown().unwrap_err().to_string();
        assert!(err.contains("'oops'"), "{err}");
    }

    #[test]
    fn claimed_positionals_pass_reject_unknown() {
        let a = args("runs stats smoke-1 --runs runs");
        assert_eq!(a.positional(0).as_deref(), Some("stats"));
        assert_eq!(a.require_positional(1, "RUN").unwrap(), "smoke-1");
        let _ = a.get("runs");
        a.reject_unknown().unwrap();
        // out-of-range positionals report what was expected
        assert_eq!(a.positional(2), None);
        let err = a.require_positional(2, "THING").unwrap_err().to_string();
        assert!(err.contains("THING"), "{err}");
    }

    #[test]
    fn reject_unknown_flags() {
        let a = args("train --schedule s.json --typo-flag 3");
        let _ = a.get("schedule");
        let err = a.reject_unknown().unwrap_err().to_string();
        assert!(err.contains("--typo-flag"), "{err}");
        // consuming it clears the rejection
        let _ = a.get("typo-flag");
        a.reject_unknown().unwrap();
    }

    #[test]
    fn get_or_default() {
        let a = args("x");
        assert_eq!(a.get_or("runs", "runs"), "runs");
    }
}
