//! Host `f32` tensor library (substrate S2).
//!
//! Everything the framework does to parameters on the Rust side — the six
//! expansion surgeries, the pure-Rust reference forward pass, optimizer
//! updates, checkpoint I/O — runs on these row-major host tensors. This is
//! deliberately *not* a general ndarray: rank ≤ 2 covers every parameter in
//! the canonical layout (DESIGN.md §7) and keeps the surgery code legible.
//!
//! The matmul family is the native training hot path ([`crate::autodiff`]
//! runs every forward *and* backward product through it), so it ships three
//! kernels tuned for row-major data:
//!
//! * [`Tensor::matmul`] — ikj order with the k-loop unrolled in blocks of
//!   four: one pass over the output row consumes four `a[i][k]` scalars and
//!   four rows of `b`, quartering the load/store traffic on the accumulator
//!   row. All-zero blocks are skipped (expansion surgery produces many
//!   exact zeros). The unrolled body keeps the naive kernel's strict
//!   left-to-right addition order per output element, so results are
//!   **bit-identical** to [`Tensor::matmul_naive`] — expansion surgery's
//!   exact-preservation guarantees (serve hot-swap byte-identical
//!   continuations) do not depend on k-offset alignment.
//! * [`Tensor::matmul_bt`] — `A · Bᵀ` with no transpose materialization
//!   (attention scores `Q Kᵀ`, and every `dC · Bᵀ` gradient product in
//!   the backward pass), register-tiled: four `B` rows per pass give four
//!   independent accumulator chains, breaking the FP-add latency chain a
//!   single dot product is stuck with.
//! * [`Tensor::matmul_at`] — `Aᵀ · C` with no transpose materialization
//!   (the `Aᵀ · dC` weight-gradient products), blocked like `matmul`:
//!   the summation (i) loop unrolled by four with zero-block skipping,
//!   quartering traffic on the `[k,n]` output.
//!
//! On top of the matmul family the raw-speed tier adds two *fused*
//! forward kernels and an online softmax (DESIGN.md §17):
//!
//! * [`Tensor::rmsnorm_matmul`] — normalize a row (RMSNorm with gain)
//!   into a stack-reused scratch row and immediately feed it to the
//!   blocked matmul body, skipping the `[m,h]` intermediate tensor the
//!   unfused `rmsnorm(x, g)` → `matmul(w)` pair would allocate and
//!   re-stream. The normalized scalars are computed by the exact
//!   [`rmsnorm_row`] arithmetic and the product by the exact `matmul`
//!   body, so the fusion is **bit-identical** to the unfused pair by
//!   construction (oracle: [`Tensor::rmsnorm_matmul_naive`]).
//! * [`Tensor::attn_pv`] — the attention `probs · V` product,
//!   register-tiled over four output columns: the four accumulators
//!   live in registers across the whole ascending-k sweep (the plain
//!   `matmul` re-loads/re-stores the output row once per k-block) and
//!   the per-element `w == 0.0` skip drops the causally-masked suffix
//!   of each probability row for free. Additions stay in ascending-k
//!   order per element with the naive kernel's skip condition, so the
//!   tile is bit-identical to [`Tensor::attn_pv_naive`].
//! * [`softmax_rows_online`] — one read sweep (running max + running
//!   normalizer, rescaled on each new max) plus one write sweep,
//!   replacing the three-sweep [`softmax_rows`]. This one is **bounded,
//!   not bit-identical**: each max update rescales the partial
//!   normalizer (`l · e^{m_old − m_new}`), reassociating the sum, so the
//!   oracle comparison is `|Δ| ≤ 1e-6` per element rather than `==`.
//!   Masked `-1e30` entries underflow to an exact `+0.0` contribution
//!   *after* any valid entry, which keeps the full-row and
//!   incremental-decode paths bitwise in agreement (see
//!   `crate::serve::kv`).
//!
//! Every tuned kernel keeps its pre-optimization body as an equivalence
//! oracle — [`Tensor::matmul_naive`], [`Tensor::matmul_bt_naive`],
//! [`Tensor::matmul_at_naive`], [`Tensor::rmsnorm_matmul_naive`],
//! [`Tensor::attn_pv_naive`] — asserted exactly equal (`==`, zero
//! tolerance) on finite inputs: each output element's additions stay in
//! the oracle's order, so every rounding step matches. The zero-skip
//! kernels (`matmul`, `matmul_at`, `attn_pv`) can still flip the *sign
//! of a zero* (`-0.0 + 0.0` is `+0.0`, and a skipped term adds
//! nothing), which `==` treats as equal; `matmul_bt` has no skip path
//! and is bitwise identical. `softmax_rows_online` is the one bounded
//! (not exact) kernel, as argued above. See DESIGN.md §10.4/§11/§17;
//! `benches/train_step.rs` and `benches/fused_kernels.rs` report the
//! speedups.

use crate::error::{Error, Result};
use crate::rng::Pcg32;

/// A dense row-major `f32` tensor of rank 1 or 2.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    // ---- constructors ----------------------------------------------------

    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Constant-filled tensor.
    pub fn full(shape: &[usize], value: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![value; shape.iter().product()] }
    }

    /// All-ones tensor.
    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor::full(shape, 1.0)
    }

    /// Build from raw data; validates the element count.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let expect: usize = shape.iter().product();
        if data.len() != expect {
            return Err(Error::Shape(format!(
                "from_vec: shape {shape:?} needs {expect} elements, got {}",
                data.len()
            )));
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    /// `std * N(0,1)` entries from the given generator.
    pub fn randn(shape: &[usize], rng: &mut Pcg32, std: f32) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    /// Identity-like 2D tensor (ones on the main diagonal).
    pub fn eye(rows: usize, cols: usize) -> Tensor {
        let mut t = Tensor::zeros(&[rows, cols]);
        for i in 0..rows.min(cols) {
            t.data[i * cols + i] = 1.0;
        }
        t
    }

    // ---- accessors ---------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Rows of a 2D tensor (or length of a 1D tensor).
    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    /// Columns of a 2D tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2, "cols() on rank-{} tensor", self.rank());
        self.shape[1]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j] = v;
    }

    /// Borrow row `i` of a 2D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    // ---- elementwise -------------------------------------------------------

    /// `self += other` (same shape).
    pub fn add_assign(&mut self, other: &Tensor) -> Result<()> {
        self.check_same_shape(other, "add_assign")?;
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        Ok(())
    }

    /// `self -= other` (same shape).
    pub fn sub_assign(&mut self, other: &Tensor) -> Result<()> {
        self.check_same_shape(other, "sub_assign")?;
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
        Ok(())
    }

    /// Multiply every element by `s`.
    pub fn scale(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// Apply `f` elementwise in place.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for a in &mut self.data {
            *a = f(*a);
        }
    }

    /// Add a 1D bias (len == cols) to every row of a 2D tensor.
    pub fn add_row_broadcast(&mut self, bias: &Tensor) -> Result<()> {
        if bias.rank() != 1 || self.rank() != 2 || bias.shape[0] != self.shape[1] {
            return Err(Error::Shape(format!(
                "add_row_broadcast: {:?} vs bias {:?}",
                self.shape, bias.shape
            )));
        }
        let c = self.shape[1];
        for i in 0..self.shape[0] {
            for j in 0..c {
                self.data[i * c + j] += bias.data[j];
            }
        }
        Ok(())
    }

    // ---- linear algebra ----------------------------------------------------

    /// Matrix product `[m,k] x [k,n] -> [m,n]` (blocked ikj order; see the
    /// module docs). Per output element the additions run in strict
    /// ascending-k order — the four `acc +=` below are separate rounded
    /// adds, never one reassociated expression — so on finite inputs the
    /// result is bit-identical to [`Tensor::matmul_naive`] and independent
    /// of `m` (row-sliced incremental-decode calls match full-tile calls
    /// exactly). Non-finite inputs can diverge: in a mixed unroll block the
    /// blocked kernel still adds `0.0 * b` terms the naive kernel skips,
    /// and `0.0 * inf` is NaN (DESIGN.md §10.4 scopes the guarantee the
    /// same way).
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || other.rank() != 2 || self.shape[1] != other.shape[0] {
            return Err(Error::Shape(format!("matmul: {:?} x {:?}", self.shape, other.shape)));
        }
        let (m, k, n) = (self.shape[0], self.shape[1], other.shape[1]);
        let kb = k / 4 * 4;
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * n..(i + 1) * n];
            let mut kk = 0;
            while kk < kb {
                let (a0, a1, a2, a3) = (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
                if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                    kk += 4; // expansion surgery produces many exact zeros
                    continue;
                }
                let b0 = &other.data[kk * n..(kk + 1) * n];
                let b1 = &other.data[(kk + 1) * n..(kk + 2) * n];
                let b2 = &other.data[(kk + 2) * n..(kk + 3) * n];
                let b3 = &other.data[(kk + 3) * n..(kk + 4) * n];
                for j in 0..n {
                    let mut acc = orow[j];
                    acc += a0 * b0[j];
                    acc += a1 * b1[j];
                    acc += a2 * b2[j];
                    acc += a3 * b3[j];
                    orow[j] = acc;
                }
                kk += 4;
            }
            for kk in kb..k {
                let a = arow[kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        Ok(out)
    }

    /// Reference straight-line ikj kernel (the pre-blocking [`Tensor::matmul`]
    /// body), kept as the equivalence oracle for the blocked kernel and the
    /// baseline case in `benches/train_step.rs`.
    pub fn matmul_naive(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || other.rank() != 2 || self.shape[1] != other.shape[0] {
            return Err(Error::Shape(format!("matmul: {:?} x {:?}", self.shape, other.shape)));
        }
        let (m, k, n) = (self.shape[0], self.shape[1], other.shape[1]);
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let orow = &mut out.data[i * n..(i + 1) * n];
            for kk in 0..k {
                let a = self.data[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        Ok(out)
    }

    /// `self^T x other`: `[m,k]^T x [m,n] -> [k,n]` without materializing
    /// the transpose — the `Aᵀ · dC` weight-gradient product shape in the
    /// autodiff backward pass. Blocked like [`Tensor::matmul`]: the i-loop
    /// (the summation axis here) is unrolled in blocks of four, so one
    /// pass over the `[k,n]` output consumes four `A` rows and four `dC`
    /// rows — quartering the load/store traffic on the output, which is
    /// the large operand in every weight-gradient product. All-zero
    /// 4-blocks of `a[i..i+4][kk]` are skipped (expansion surgery zeros).
    /// Per output element the four `acc +=` are separate rounded adds in
    /// ascending-i order, so on finite inputs the result equals
    /// [`Tensor::matmul_at_naive`] exactly under `==` (same caveat as
    /// `matmul`: a mixed block still adds exact `0.0 * b` terms the
    /// naive kernel skips — that extra add can flip a `-0.0`
    /// accumulator to `+0.0`, and produces NaN for non-finite `b`).
    pub fn matmul_at(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || other.rank() != 2 || self.shape[0] != other.shape[0] {
            return Err(Error::Shape(format!("matmul_at: {:?}^T x {:?}", self.shape, other.shape)));
        }
        let (m, k, n) = (self.shape[0], self.shape[1], other.shape[1]);
        let mb = m / 4 * 4;
        let mut out = Tensor::zeros(&[k, n]);
        let mut i = 0;
        while i < mb {
            let a0row = &self.data[i * k..(i + 1) * k];
            let a1row = &self.data[(i + 1) * k..(i + 2) * k];
            let a2row = &self.data[(i + 2) * k..(i + 3) * k];
            let a3row = &self.data[(i + 3) * k..(i + 4) * k];
            let b0 = &other.data[i * n..(i + 1) * n];
            let b1 = &other.data[(i + 1) * n..(i + 2) * n];
            let b2 = &other.data[(i + 2) * n..(i + 3) * n];
            let b3 = &other.data[(i + 3) * n..(i + 4) * n];
            for kk in 0..k {
                let (a0, a1, a2, a3) = (a0row[kk], a1row[kk], a2row[kk], a3row[kk]);
                if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                    continue;
                }
                let orow = &mut out.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    let mut acc = orow[j];
                    acc += a0 * b0[j];
                    acc += a1 * b1[j];
                    acc += a2 * b2[j];
                    acc += a3 * b3[j];
                    orow[j] = acc;
                }
            }
            i += 4;
        }
        for i in mb..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let brow = &other.data[i * n..(i + 1) * n];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        Ok(out)
    }

    /// Reference straight-line rank-1-update kernel (the pre-blocking
    /// [`Tensor::matmul_at`] body), kept as its equivalence oracle and
    /// bench baseline.
    pub fn matmul_at_naive(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || other.rank() != 2 || self.shape[0] != other.shape[0] {
            return Err(Error::Shape(format!("matmul_at: {:?}^T x {:?}", self.shape, other.shape)));
        }
        let (m, k, n) = (self.shape[0], self.shape[1], other.shape[1]);
        let mut out = Tensor::zeros(&[k, n]);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let brow = &other.data[i * n..(i + 1) * n];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        Ok(out)
    }

    /// `self x other^T`: `[m,k] x [n,k] -> [m,n]` without materializing the
    /// transpose — attention scores `Q Kᵀ` on the forward and every
    /// `dC · Bᵀ` gradient product on the backward. Register-tiled: four
    /// `B` rows are dotted against one `A` row per pass, giving four
    /// independent accumulator chains (the single-accumulator dot product
    /// is FP-add *latency* bound — f32 addition cannot be reassociated, so
    /// the compiler cannot break the chain itself) and one `arow` load
    /// shared across the four. Each output element keeps its own
    /// accumulator in strict ascending-k order, so the tile is
    /// bit-identical to [`Tensor::matmul_bt_naive`] — tiling regroups
    /// *which* dot products run together, never the additions inside one.
    pub fn matmul_bt(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || other.rank() != 2 || self.shape[1] != other.shape[1] {
            return Err(Error::Shape(format!("matmul_bt: {:?} x {:?}^T", self.shape, other.shape)));
        }
        let (m, k, n) = (self.shape[0], self.shape[1], other.shape[0]);
        let nb = n / 4 * 4;
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * n..(i + 1) * n];
            let mut j = 0;
            while j < nb {
                let b0 = &other.data[j * k..(j + 1) * k];
                let b1 = &other.data[(j + 1) * k..(j + 2) * k];
                let b2 = &other.data[(j + 2) * k..(j + 3) * k];
                let b3 = &other.data[(j + 3) * k..(j + 4) * k];
                let (mut c0, mut c1, mut c2, mut c3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for kk in 0..k {
                    let a = arow[kk];
                    c0 += a * b0[kk];
                    c1 += a * b1[kk];
                    c2 += a * b2[kk];
                    c3 += a * b3[kk];
                }
                orow[j] = c0;
                orow[j + 1] = c1;
                orow[j + 2] = c2;
                orow[j + 3] = c3;
                j += 4;
            }
            for j in nb..n {
                let brow = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                orow[j] = acc;
            }
        }
        Ok(out)
    }

    /// Reference row-dot-product kernel (the pre-tiling
    /// [`Tensor::matmul_bt`] body), kept as its equivalence oracle and
    /// bench baseline.
    pub fn matmul_bt_naive(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || other.rank() != 2 || self.shape[1] != other.shape[1] {
            return Err(Error::Shape(format!("matmul_bt: {:?} x {:?}^T", self.shape, other.shape)));
        }
        let (m, k, n) = (self.shape[0], self.shape[1], other.shape[0]);
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                out.data[i * n + j] = acc;
            }
        }
        Ok(out)
    }

    /// Fused RMSNorm + matmul: `rmsnorm(self, g).matmul(w)` in one pass
    /// (`self` is `[m,h]`, `g` is `[h]`, `w` is `[h,n]`, result `[m,n]`).
    /// Each input row is normalized into a scratch row reused across the
    /// whole call — the `[m,h]` intermediate the unfused pair would
    /// allocate, fill, and re-stream never exists — and the scratch row
    /// is consumed immediately by the blocked `matmul` body while it is
    /// still cache-hot. Normalization uses the exact [`rmsnorm_row`]
    /// arithmetic and the product the exact [`Tensor::matmul`] body, so
    /// the result is bit-identical to the unfused pair (and to
    /// [`Tensor::rmsnorm_matmul_naive`]) on finite inputs, with the same
    /// sign-of-zero caveat as `matmul`.
    pub fn rmsnorm_matmul(&self, g: &Tensor, w: &Tensor) -> Result<Tensor> {
        if self.rank() != 2
            || g.rank() != 1
            || w.rank() != 2
            || self.shape[1] != g.shape[0]
            || self.shape[1] != w.shape[0]
        {
            return Err(Error::Shape(format!(
                "rmsnorm_matmul: {:?} (g {:?}) x {:?}",
                self.shape, g.shape, w.shape
            )));
        }
        let (m, h, n) = (self.shape[0], self.shape[1], w.shape[1]);
        let hb = h / 4 * 4;
        let mut out = Tensor::zeros(&[m, n]);
        let mut nrm = vec![0.0f32; h];
        for i in 0..m {
            rmsnorm_row(&self.data[i * h..(i + 1) * h], &g.data, &mut nrm);
            let orow = &mut out.data[i * n..(i + 1) * n];
            let mut kk = 0;
            while kk < hb {
                let (a0, a1, a2, a3) = (nrm[kk], nrm[kk + 1], nrm[kk + 2], nrm[kk + 3]);
                if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                    kk += 4;
                    continue;
                }
                let b0 = &w.data[kk * n..(kk + 1) * n];
                let b1 = &w.data[(kk + 1) * n..(kk + 2) * n];
                let b2 = &w.data[(kk + 2) * n..(kk + 3) * n];
                let b3 = &w.data[(kk + 3) * n..(kk + 4) * n];
                for j in 0..n {
                    let mut acc = orow[j];
                    acc += a0 * b0[j];
                    acc += a1 * b1[j];
                    acc += a2 * b2[j];
                    acc += a3 * b3[j];
                    orow[j] = acc;
                }
                kk += 4;
            }
            for kk in hb..h {
                let a = nrm[kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &w.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        Ok(out)
    }

    /// Unfused reference for [`Tensor::rmsnorm_matmul`]: materialize the
    /// normalized rows, then run the straight-line [`Tensor::matmul_naive`]
    /// body. Kept as the fusion's equivalence oracle and bench baseline.
    pub fn rmsnorm_matmul_naive(&self, g: &Tensor, w: &Tensor) -> Result<Tensor> {
        if self.rank() != 2
            || g.rank() != 1
            || w.rank() != 2
            || self.shape[1] != g.shape[0]
            || self.shape[1] != w.shape[0]
        {
            return Err(Error::Shape(format!(
                "rmsnorm_matmul: {:?} (g {:?}) x {:?}",
                self.shape, g.shape, w.shape
            )));
        }
        let (m, h) = (self.shape[0], self.shape[1]);
        let mut nrm = Tensor::zeros(&[m, h]);
        for i in 0..m {
            let row = &self.data[i * h..(i + 1) * h];
            rmsnorm_row(row, &g.data, &mut nrm.data[i * h..(i + 1) * h]);
        }
        nrm.matmul_naive(w)
    }

    /// The attention `probs · V` product (`self` is `[m,t]` probabilities,
    /// `v` is `[t,dv]`, result `[m,dv]`), register-tiled over four output
    /// columns: the four accumulators live in registers for the whole
    /// ascending-k sweep instead of round-tripping through the output row
    /// once per k-block as [`Tensor::matmul`] does, and the per-element
    /// `w == 0.0` skip drops every causally-masked (softmax-underflowed)
    /// probability without touching its `V` row. Additions per output
    /// element keep the naive kernel's ascending-k order and skip
    /// condition, so the result is bit-identical to
    /// [`Tensor::attn_pv_naive`] on finite inputs (sign-of-zero caveat as
    /// `matmul`).
    pub fn attn_pv(&self, v: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || v.rank() != 2 || self.shape[1] != v.shape[0] {
            return Err(Error::Shape(format!("attn_pv: {:?} x {:?}", self.shape, v.shape)));
        }
        let (m, t, dv) = (self.shape[0], self.shape[1], v.shape[1]);
        let db = dv / 4 * 4;
        let mut out = Tensor::zeros(&[m, dv]);
        for i in 0..m {
            let prow = &self.data[i * t..(i + 1) * t];
            let orow = &mut out.data[i * dv..(i + 1) * dv];
            let mut j = 0;
            while j < db {
                let (mut c0, mut c1, mut c2, mut c3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for (kk, &w) in prow.iter().enumerate() {
                    if w == 0.0 {
                        continue; // masked / underflowed probability
                    }
                    let vrow = &v.data[kk * dv..(kk + 1) * dv];
                    c0 += w * vrow[j];
                    c1 += w * vrow[j + 1];
                    c2 += w * vrow[j + 2];
                    c3 += w * vrow[j + 3];
                }
                orow[j] = c0;
                orow[j + 1] = c1;
                orow[j + 2] = c2;
                orow[j + 3] = c3;
                j += 4;
            }
            for j in db..dv {
                let mut acc = 0.0f32;
                for (kk, &w) in prow.iter().enumerate() {
                    if w == 0.0 {
                        continue;
                    }
                    acc += w * v.data[kk * dv + j];
                }
                orow[j] = acc;
            }
        }
        Ok(out)
    }

    /// Reference straight-line ikj kernel for [`Tensor::attn_pv`] (the
    /// [`Tensor::matmul_naive`] body with the same per-element zero skip),
    /// kept as its equivalence oracle and bench baseline.
    pub fn attn_pv_naive(&self, v: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || v.rank() != 2 || self.shape[1] != v.shape[0] {
            return Err(Error::Shape(format!("attn_pv: {:?} x {:?}", self.shape, v.shape)));
        }
        let (m, t, dv) = (self.shape[0], self.shape[1], v.shape[1]);
        let mut out = Tensor::zeros(&[m, dv]);
        for i in 0..m {
            let prow = &self.data[i * t..(i + 1) * t];
            let orow = &mut out.data[i * dv..(i + 1) * dv];
            for (kk, &w) in prow.iter().enumerate() {
                if w == 0.0 {
                    continue;
                }
                let vrow = &v.data[kk * dv..(kk + 1) * dv];
                for j in 0..dv {
                    orow[j] += w * vrow[j];
                }
            }
        }
        Ok(out)
    }

    /// Transposed copy of a 2D tensor.
    pub fn transpose(&self) -> Result<Tensor> {
        if self.rank() != 2 {
            return Err(Error::Shape(format!("transpose: rank {} tensor", self.rank())));
        }
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.data[j * m + i] = self.data[i * n + j];
            }
        }
        Ok(out)
    }

    // ---- concatenation / slicing (the expansion primitives) ----------------

    /// `[m, a] ++ [m, b] -> [m, a+b]` — column append (e.g. Eq. 6).
    pub fn concat_cols(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || other.rank() != 2 || self.shape[0] != other.shape[0] {
            return Err(Error::Shape(format!("concat_cols: {:?} ++ {:?}", self.shape, other.shape)));
        }
        let (m, a, b) = (self.shape[0], self.shape[1], other.shape[1]);
        let mut out = Tensor::zeros(&[m, a + b]);
        for i in 0..m {
            out.data[i * (a + b)..i * (a + b) + a].copy_from_slice(self.row(i));
            out.data[i * (a + b) + a..(i + 1) * (a + b)].copy_from_slice(other.row(i));
        }
        Ok(out)
    }

    /// `[a, n] ++ [b, n] -> [a+b, n]` — row append (e.g. Eq. 8).
    pub fn concat_rows(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || other.rank() != 2 || self.shape[1] != other.shape[1] {
            return Err(Error::Shape(format!("concat_rows: {:?} ++ {:?}", self.shape, other.shape)));
        }
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Ok(Tensor { shape: vec![self.shape[0] + other.shape[0], self.shape[1]], data })
    }

    /// 1D concatenation (e.g. Eq. 7 bias growth).
    pub fn concat_1d(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 1 || other.rank() != 1 {
            return Err(Error::Shape(format!("concat_1d: {:?} ++ {:?}", self.shape, other.shape)));
        }
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Ok(Tensor { shape: vec![self.shape[0] + other.shape[0]], data })
    }

    /// Copy of rows `[lo, hi)` of a 2D tensor (W^O split extraction, Eq. 15).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Result<Tensor> {
        if self.rank() != 2 || hi > self.shape[0] || lo > hi {
            return Err(Error::Shape(format!("slice_rows[{lo}..{hi}] of {:?}", self.shape)));
        }
        let n = self.shape[1];
        Ok(Tensor { shape: vec![hi - lo, n], data: self.data[lo * n..hi * n].to_vec() })
    }

    /// Copy of columns `[lo, hi)` of a 2D tensor.
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Result<Tensor> {
        if self.rank() != 2 || hi > self.shape[1] || lo > hi {
            return Err(Error::Shape(format!("slice_cols[{lo}..{hi}] of {:?}", self.shape)));
        }
        let (m, n) = (self.shape[0], self.shape[1]);
        let w = hi - lo;
        let mut out = Tensor::zeros(&[m, w]);
        for i in 0..m {
            out.data[i * w..(i + 1) * w].copy_from_slice(&self.data[i * n + lo..i * n + hi]);
        }
        Ok(out)
    }

    // ---- comparison ---------------------------------------------------------

    /// `max_i |self_i - other_i|`; error on shape mismatch.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        self.check_same_shape(other, "max_abs_diff")?;
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max))
    }

    /// Largest absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().map(|a| a.abs()).fold(0.0, f32::max)
    }

    /// True if every element is finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|a| a.is_finite())
    }

    fn check_same_shape(&self, other: &Tensor, op: &str) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!("{op}: {:?} vs {:?}", self.shape, other.shape)));
        }
        Ok(())
    }
}

/// RMSNorm one row with a per-feature gain: `out[j] = row[j] * g[j] /
/// sqrt(mean(row²))`. This free function is the *single* definition of
/// the normalization arithmetic — `model::rmsnorm`, the fused
/// [`Tensor::rmsnorm_matmul`], and the serve KV remap all call it, which
/// is what makes "fused equals unfused" and "remap equals fresh prime"
/// bit-identity arguments hold by construction rather than by luck.
#[inline]
pub fn rmsnorm_row(row: &[f32], g: &[f32], out: &mut [f32]) {
    let h = row.len();
    let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / h as f32;
    let denom = ms.sqrt();
    for j in 0..h {
        out[j] = row[j] * g[j] / denom;
    }
}

/// Numerically-stable softmax over the last axis of a 2D tensor, in place.
pub fn softmax_rows(t: &mut Tensor) {
    let (m, n) = (t.shape()[0], t.shape()[1]);
    for i in 0..m {
        let row = t.row_mut(i);
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for x in row.iter_mut() {
            *x = (*x - max).exp();
            sum += *x;
        }
        let _ = m; // silence clippy on small fn
        for x in row.iter_mut() {
            *x /= sum;
        }
        let _ = n;
    }
}

/// Online (single-read-sweep) softmax over the last axis, in place.
///
/// Per row this carries a running max `m` and running normalizer `l`;
/// when a new max arrives the partial normalizer is rescaled by
/// `e^{m_old − m_new}` (which is exactly `0.0` on the first element,
/// seeding `l = 1.0`). One read sweep plus one write sweep replaces the
/// three sweeps of [`softmax_rows`] (max, exp+sum, divide) — the win is
/// one fewer pass over a row that no longer fits in registers once
/// sequences grow.
///
/// Two properties the serve/autodiff paths rely on:
///
/// * **Bounded vs the oracle, not bit-identical**: rescaling reassociates
///   the normalizer sum, so elements can differ from [`softmax_rows`] by
///   a few ULPs (tests bound it at `1e-6`). All attention paths (full
///   forward, taped forward, incremental decode) switch to the online
///   pass *together*, so cross-path bit-identity is preserved.
/// * **Masked suffix is a bitwise no-op**: a causally-masked score
///   (`model::MASK_VALUE` = `-1e30`) processed after any valid score
///   satisfies `x ≤ m` and `e^{x−m}` underflows to exactly `0.0`, so it
///   changes neither `m` nor `l` — the `(m, l)` pair for a full row with
///   masked suffix is bitwise the pair for the unmasked prefix alone,
///   which keeps full-tile and incremental-decode attention in exact
///   agreement.
pub fn softmax_rows_online(t: &mut Tensor) {
    let m = t.shape()[0];
    for i in 0..m {
        softmax_row_online(t.row_mut(i));
    }
}

/// The single-row body of [`softmax_rows_online`]; also the row pass used
/// by the serve KV cache's incremental `attend` (`crate::serve::kv`), so
/// the two stay one definition.
#[inline]
pub fn softmax_row_online(row: &mut [f32]) {
    let mut max = f32::NEG_INFINITY;
    let mut norm = 0.0f32;
    for &x in row.iter() {
        if x > max {
            norm = norm * (max - x).exp() + 1.0;
            max = x;
        } else {
            norm += (x - max).exp();
        }
    }
    for x in row.iter_mut() {
        *x = (*x - max).exp() / norm;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(rows: usize, cols: usize, data: &[f32]) -> Tensor {
        Tensor::from_vec(&[rows, cols], data.to_vec()).unwrap()
    }

    #[test]
    fn constructors() {
        assert_eq!(Tensor::zeros(&[2, 3]).numel(), 6);
        assert_eq!(Tensor::ones(&[4]).data(), &[1.0; 4]);
        assert_eq!(Tensor::full(&[2], 2.5).data(), &[2.5, 2.5]);
        let e = Tensor::eye(2, 3);
        assert_eq!(e.data(), &[1.0, 0.0, 0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 3]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 4]).is_ok());
    }

    #[test]
    fn randn_statistics() {
        let mut rng = Pcg32::seeded(1);
        let t = Tensor::randn(&[100, 100], &mut rng, 0.5);
        let mean: f32 = t.data().iter().sum::<f32>() / t.numel() as f32;
        let var: f32 = t.data().iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / t.numel() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 0.25).abs() < 0.02, "var {var}");
    }

    #[test]
    fn matmul_known_values() {
        let a = t2(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = t2(2, 2, &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.matmul(&b).unwrap().data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = t2(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let out = a.matmul(&Tensor::eye(3, 3)).unwrap();
        assert_eq!(out, a);
    }

    #[test]
    fn matmul_shape_errors() {
        let a = t2(2, 3, &[0.0; 6]);
        assert!(a.matmul(&t2(2, 3, &[0.0; 6])).is_err());
        assert!(a.matmul(&Tensor::ones(&[3])).is_err());
    }

    #[test]
    fn blocked_matmul_is_bitexact_with_naive_kernel() {
        // the unrolled body preserves strict ascending-k addition order, so
        // equality is exact, not approximate — the serve hot-swap's
        // byte-identical guarantee rides on this. Shapes hit the unrolled
        // body, the tail (k % 4 != 0), and degenerate single-row/col cases.
        let mut rng = Pcg32::seeded(40);
        for (m, k, n) in [(1, 1, 1), (3, 4, 5), (5, 7, 3), (8, 9, 1), (2, 13, 17), (16, 32, 8)] {
            let a = Tensor::randn(&[m, k], &mut rng, 1.0);
            let b = Tensor::randn(&[k, n], &mut rng, 1.0);
            let blocked = a.matmul(&b).unwrap();
            let naive = a.matmul_naive(&b).unwrap();
            assert_eq!(blocked, naive, "({m},{k},{n}): blocked diverged from naive");
        }
    }

    #[test]
    fn blocked_matmul_handles_zero_blocks_and_scattered_zeros() {
        // all-zero k-blocks take the skip path; scattered zeros inside
        // mixed blocks take the add-exact-zero path; both must stay
        // bit-identical to the naive per-element skip
        let mut rng = Pcg32::seeded(41);
        let mut a = Tensor::randn(&[3, 12], &mut rng, 1.0);
        for i in 0..3 {
            for kk in 4..8 {
                a.set(i, kk, 0.0); // one full unroll block of zeros
            }
        }
        a.set(0, 1, 0.0); // scattered zero inside a mixed block
        a.set(2, 10, 0.0);
        let b = Tensor::randn(&[12, 6], &mut rng, 1.0);
        assert_eq!(a.matmul(&b).unwrap(), a.matmul_naive(&b).unwrap());
    }

    #[test]
    fn matmul_at_matches_explicit_transpose() {
        let mut rng = Pcg32::seeded(42);
        let a = Tensor::randn(&[5, 4], &mut rng, 1.0);
        let b = Tensor::randn(&[5, 7], &mut rng, 1.0);
        let direct = a.matmul_at(&b).unwrap();
        assert_eq!(direct.shape(), &[4, 7]);
        let via_t = a.transpose().unwrap().matmul_naive(&b).unwrap();
        assert!(direct.max_abs_diff(&via_t).unwrap() < 1e-5);
    }

    #[test]
    fn blocked_matmul_at_is_bitexact_with_naive_kernel() {
        // shapes cover the 4-wide i-unroll body, the i-tail (m % 4 != 0),
        // and degenerate single-row/col cases
        let mut rng = Pcg32::seeded(43);
        for (m, k, n) in [(1, 1, 1), (3, 4, 5), (4, 3, 6), (8, 5, 7), (13, 16, 9), (16, 32, 8)] {
            let a = Tensor::randn(&[m, k], &mut rng, 1.0);
            let b = Tensor::randn(&[m, n], &mut rng, 1.0);
            let blocked = a.matmul_at(&b).unwrap();
            let naive = a.matmul_at_naive(&b).unwrap();
            assert_eq!(blocked, naive, "({m},{k},{n}): blocked matmul_at diverged from naive");
        }
    }

    #[test]
    fn blocked_matmul_at_handles_zero_blocks_and_scattered_zeros() {
        // a full i-block of zeros in one column takes the skip path; a
        // scattered zero inside a mixed block takes the add-exact-zero
        // path; both must agree with the naive per-element skip
        let mut rng = Pcg32::seeded(44);
        let mut a = Tensor::randn(&[9, 6], &mut rng, 1.0);
        for i in 0..4 {
            a.set(i, 2, 0.0); // rows 0..4 zero in column 2: one skipped block
        }
        a.set(5, 3, 0.0); // scattered zero inside a mixed block
        a.set(8, 0, 0.0); // zero in the i-tail
        let b = Tensor::randn(&[9, 5], &mut rng, 1.0);
        assert_eq!(a.matmul_at(&b).unwrap(), a.matmul_at_naive(&b).unwrap());
    }

    #[test]
    fn matmul_at_shape_errors() {
        let a = t2(2, 3, &[0.0; 6]);
        assert!(a.matmul_at(&t2(3, 2, &[0.0; 6])).is_err());
        assert!(a.matmul_at(&Tensor::ones(&[2])).is_err());
        assert!(a.matmul_at_naive(&t2(3, 2, &[0.0; 6])).is_err());
    }

    #[test]
    fn matmul_bt_matches_explicit_transpose() {
        let mut rng = Pcg32::seeded(2);
        let a = Tensor::randn(&[4, 6], &mut rng, 1.0);
        let b = Tensor::randn(&[5, 6], &mut rng, 1.0);
        let direct = a.matmul_bt(&b).unwrap();
        let via_t = a.matmul(&b.transpose().unwrap()).unwrap();
        assert!(direct.max_abs_diff(&via_t).unwrap() < 1e-6);
    }

    #[test]
    fn tiled_matmul_bt_is_bitexact_with_naive_kernel() {
        // per output element the tiled kernel runs the same ascending-k
        // accumulator as the naive row-dot, so equality is exact. Shapes
        // cover the 4-wide j-tile, the j-tail (n % 4 != 0), k == 1, and
        // single-row/col degenerates.
        let mut rng = Pcg32::seeded(45);
        for (m, k, n) in [(1, 1, 1), (3, 5, 4), (4, 6, 7), (2, 1, 9), (7, 13, 16), (8, 32, 6)] {
            let a = Tensor::randn(&[m, k], &mut rng, 1.0);
            let b = Tensor::randn(&[n, k], &mut rng, 1.0);
            let tiled = a.matmul_bt(&b).unwrap();
            let naive = a.matmul_bt_naive(&b).unwrap();
            assert_eq!(tiled, naive, "({m},{k},{n}): tiled matmul_bt diverged from naive");
        }
    }

    #[test]
    fn fused_rmsnorm_matmul_is_bitexact_with_naive_and_unfused() {
        // the fused kernel must equal BOTH the straight-line oracle and the
        // materialize-then-blocked-matmul pair under `==` — layer_tail and
        // the tape swap the unfused pair for the fusion, and every forward
        // bit-identity guarantee (taped == reference, incremental == full)
        // rides on this. Shapes cover h % 4 tails and degenerate rows.
        let mut rng = Pcg32::seeded(46);
        for (m, h, n) in [(1, 1, 1), (2, 4, 5), (3, 6, 4), (5, 8, 8), (4, 13, 7), (7, 32, 16)] {
            let x = Tensor::randn(&[m, h], &mut rng, 1.0);
            let g = Tensor::randn(&[h], &mut rng, 0.5);
            let w = Tensor::randn(&[h, n], &mut rng, 1.0);
            let fused = x.rmsnorm_matmul(&g, &w).unwrap();
            let naive = x.rmsnorm_matmul_naive(&g, &w).unwrap();
            assert_eq!(fused, naive, "({m},{h},{n}): fused diverged from naive oracle");
            let mut nrm = Tensor::zeros(&[m, h]);
            for i in 0..m {
                let mut out = vec![0.0f32; h];
                rmsnorm_row(x.row(i), g.data(), &mut out);
                nrm.row_mut(i).copy_from_slice(&out);
            }
            let unfused = nrm.matmul(&w).unwrap();
            assert_eq!(fused, unfused, "({m},{h},{n}): fused diverged from unfused pair");
        }
    }

    #[test]
    fn rmsnorm_matmul_shape_errors() {
        let x = t2(2, 3, &[0.0; 6]);
        let g = Tensor::ones(&[3]);
        assert!(x.rmsnorm_matmul(&g, &t2(4, 2, &[0.0; 8])).is_err()); // w rows != h
        assert!(x.rmsnorm_matmul(&Tensor::ones(&[2]), &t2(3, 2, &[0.0; 6])).is_err());
        assert!(x.rmsnorm_matmul_naive(&g, &t2(4, 2, &[0.0; 8])).is_err());
    }

    #[test]
    fn tiled_attn_pv_is_bitexact_with_naive_kernel() {
        // probability rows carry exact zeros (causally-masked suffix after
        // softmax underflow); both kernels skip them with the same
        // condition and keep ascending-k addition order, so equality is
        // exact. Shapes cover the 4-wide column tile, the dv % 4 tail, and
        // single-row/col degenerates.
        let mut rng = Pcg32::seeded(47);
        for (m, t, dv) in [(1, 1, 1), (3, 4, 5), (4, 6, 8), (2, 9, 3), (6, 16, 12), (5, 7, 16)] {
            let mut p = Tensor::randn(&[m, t], &mut rng, 1.0);
            p.map_inplace(|x| x.abs());
            for i in 0..m {
                let cut = i.min(t - 1);
                for j in cut + 1..t {
                    p.set(i, j, 0.0); // masked suffix, as softmax leaves it
                }
            }
            let v = Tensor::randn(&[t, dv], &mut rng, 1.0);
            let tiled = p.attn_pv(&v).unwrap();
            let naive = p.attn_pv_naive(&v).unwrap();
            assert_eq!(tiled, naive, "({m},{t},{dv}): tiled attn_pv diverged from naive");
            // same skip condition + addition order as the general blocked
            // kernel's oracle, so the fused path equals plain matmul too
            assert_eq!(tiled, p.matmul_naive(&v).unwrap());
        }
    }

    #[test]
    fn attn_pv_shape_errors() {
        let p = t2(2, 3, &[0.0; 6]);
        assert!(p.attn_pv(&t2(2, 4, &[0.0; 8])).is_err());
        assert!(p.attn_pv_naive(&t2(2, 4, &[0.0; 8])).is_err());
    }

    #[test]
    fn online_softmax_is_bounded_against_two_pass_oracle() {
        // the online pass reassociates the normalizer sum (rescale on each
        // new max), so the comparison is bounded, not `==` — the bound here
        // is the one DESIGN.md §17 documents
        let mut rng = Pcg32::seeded(48);
        for (m, n) in [(1, 1), (3, 7), (8, 16), (4, 33)] {
            let base = Tensor::randn(&[m, n], &mut rng, 3.0);
            let mut online = base.clone();
            softmax_rows_online(&mut online);
            let mut oracle = base.clone();
            softmax_rows(&mut oracle);
            assert!(
                online.max_abs_diff(&oracle).unwrap() <= 1e-6,
                "({m},{n}): online softmax drifted past the documented bound"
            );
            for i in 0..m {
                let s: f32 = online.row(i).iter().sum();
                assert!((s - 1.0).abs() < 1e-5, "row {i} sum {s}");
            }
        }
    }

    #[test]
    fn online_softmax_masked_suffix_is_bitwise_noop() {
        // a -1e30-masked score processed after any valid score must leave
        // the (max, normalizer) pair bitwise unchanged — this is the
        // property that keeps full-tile attention rows and incremental
        // KV-decode rows in exact agreement (DESIGN.md §17)
        let mut rng = Pcg32::seeded(49);
        for t in [1usize, 2, 5, 9] {
            let scores: Vec<f32> = (0..t).map(|_| rng.uniform_f32() * 8.0 - 4.0).collect();
            let mut full: Vec<f32> = scores.clone();
            full.extend([-1e30f32; 3]);
            softmax_row_online(&mut full);
            let mut prefix = scores.clone();
            softmax_row_online(&mut prefix);
            for j in 0..t {
                assert_eq!(full[j].to_bits(), prefix[j].to_bits(), "t={t} j={j}");
            }
            for x in &full[t..] {
                assert_eq!(*x, 0.0, "masked entry must land at exactly zero");
            }
        }
    }

    #[test]
    fn matmul_bt_shape_errors() {
        let a = t2(2, 3, &[0.0; 6]);
        assert!(a.matmul_bt(&t2(3, 2, &[0.0; 6])).is_err());
        assert!(a.matmul_bt_naive(&t2(3, 2, &[0.0; 6])).is_err());
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg32::seeded(3);
        let a = Tensor::randn(&[3, 7], &mut rng, 1.0);
        assert_eq!(a.transpose().unwrap().transpose().unwrap(), a);
    }

    #[test]
    fn concat_cols_layout() {
        let a = t2(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = t2(2, 1, &[9.0, 8.0]);
        let c = a.concat_cols(&b).unwrap();
        assert_eq!(c.shape(), &[2, 3]);
        assert_eq!(c.data(), &[1.0, 2.0, 9.0, 3.0, 4.0, 8.0]);
    }

    #[test]
    fn concat_rows_layout() {
        let a = t2(1, 2, &[1.0, 2.0]);
        let b = t2(2, 2, &[3.0, 4.0, 5.0, 6.0]);
        let c = a.concat_rows(&b).unwrap();
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn concat_shape_errors() {
        let a = t2(2, 2, &[0.0; 4]);
        assert!(a.concat_cols(&t2(3, 1, &[0.0; 3])).is_err());
        assert!(a.concat_rows(&t2(1, 3, &[0.0; 3])).is_err());
        assert!(Tensor::ones(&[2]).concat_1d(&t2(1, 1, &[0.0])).is_err());
    }

    #[test]
    fn slices_extract_expected_windows() {
        let a = t2(3, 3, &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.slice_rows(1, 3).unwrap().data(), &[3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.slice_cols(0, 2).unwrap().data(), &[0.0, 1.0, 3.0, 4.0, 6.0, 7.0]);
        assert!(a.slice_rows(2, 4).is_err());
        assert!(a.slice_cols(2, 1).is_err());
    }

    #[test]
    fn slice_concat_roundtrip() {
        let mut rng = Pcg32::seeded(4);
        let a = Tensor::randn(&[5, 6], &mut rng, 1.0);
        let left = a.slice_cols(0, 2).unwrap();
        let right = a.slice_cols(2, 6).unwrap();
        assert_eq!(left.concat_cols(&right).unwrap(), a);
        let top = a.slice_rows(0, 3).unwrap();
        let bottom = a.slice_rows(3, 5).unwrap();
        assert_eq!(top.concat_rows(&bottom).unwrap(), a);
    }

    #[test]
    fn elementwise_ops() {
        let mut a = t2(1, 3, &[1.0, -2.0, 3.0]);
        a.scale(2.0);
        assert_eq!(a.data(), &[2.0, -4.0, 6.0]);
        a.map_inplace(|x| x.max(0.0));
        assert_eq!(a.data(), &[2.0, 0.0, 6.0]);
        a.add_assign(&t2(1, 3, &[1.0, 1.0, 1.0])).unwrap();
        assert_eq!(a.data(), &[3.0, 1.0, 7.0]);
        a.sub_assign(&t2(1, 3, &[1.0, 1.0, 1.0])).unwrap();
        assert_eq!(a.data(), &[2.0, 0.0, 6.0]);
        assert!(a.add_assign(&Tensor::ones(&[3])).is_err());
    }

    #[test]
    fn bias_broadcast() {
        let mut a = t2(2, 2, &[0.0, 0.0, 1.0, 1.0]);
        a.add_row_broadcast(&Tensor::from_vec(&[2], vec![10.0, 20.0]).unwrap()).unwrap();
        assert_eq!(a.data(), &[10.0, 20.0, 11.0, 21.0]);
        assert!(a.add_row_broadcast(&Tensor::ones(&[3])).is_err());
    }

    #[test]
    fn softmax_rows_normalizes() {
        let mut a = t2(2, 3, &[1.0, 2.0, 3.0, 1000.0, 1000.0, 1000.0]);
        softmax_rows(&mut a);
        for i in 0..2 {
            let s: f32 = a.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        // large-logit row is stable and uniform
        assert!((a.at(1, 0) - 1.0 / 3.0).abs() < 1e-6);
        // softmax is monotone in its inputs
        assert!(a.at(0, 2) > a.at(0, 1) && a.at(0, 1) > a.at(0, 0));
    }

    #[test]
    fn diff_helpers() {
        let a = t2(1, 2, &[1.0, 2.0]);
        let b = t2(1, 2, &[1.5, 1.0]);
        assert_eq!(a.max_abs_diff(&b).unwrap(), 1.0);
        assert_eq!(b.max_abs(), 1.5);
        assert!(a.max_abs_diff(&Tensor::ones(&[2])).is_err());
        let mut c = a.clone();
        c.data_mut()[0] = f32::NAN;
        assert!(!c.all_finite());
        assert!(a.all_finite());
    }
}
