//! Scoped-thread worker pool (S17a) — the one parallelism seam.
//!
//! All three compute fan-outs in the repo — data-parallel native training
//! ([`crate::autodiff::loss_and_grads_pooled`] over batch rows), the
//! within-row per-head backward that takes over when the batch is a
//! single row ([`crate::autodiff::backward_seq_pooled`], DESIGN.md §17),
//! and the serve scheduler's per-slot decode ([`crate::serve`]) — run
//! through this [`Pool`], so thread policy lives in exactly one place.
//! The pool is a
//! *sizing policy*, not a thread cache: each `map`/`map_mut` call spawns
//! scoped OS threads (`std::thread::scope`) that never outlive the call,
//! so no `'static` bounds, no channels, no shutdown protocol — the same
//! property the serve scheduler's old ad-hoc `thread::scope` loop relied
//! on, now shared.
//!
//! Sizing: `Pool::from_env()` honours `TEXPAND_THREADS` (the CLI's
//! `--threads` flag overrides it per run) and falls back to
//! `std::thread::available_parallelism`. Work is split into contiguous
//! index chunks, one per worker, sizes differing by at most one — the
//! items both call sites feed (batch rows, decode slots) are
//! near-uniform cost, so static chunking wastes nothing and keeps the
//! pool dependency-free.
//!
//! Determinism: the pool itself adds none and removes none — results are
//! returned in item order regardless of which worker produced them, and
//! callers that *reduce* results must do so in a fixed order (see the
//! deterministic tree reduction in [`crate::autodiff::backward`] and
//! DESIGN.md §11).

use std::num::NonZeroUsize;

/// Worker count resolution: `TEXPAND_THREADS` env var (values `>= 1`;
/// unset, empty, `0` or unparsable fall through), else the machine's
/// available parallelism, else 1.
pub fn env_threads() -> usize {
    if let Ok(v) = std::env::var("TEXPAND_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
}

/// A fixed-width scoped-thread pool (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Pool of exactly `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// Pool sized by [`env_threads`].
    pub fn from_env() -> Pool {
        Pool::new(env_threads())
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Apply `f(index, &item)` to every item, fanning out across the
    /// pool's workers; results come back in item order. With one worker
    /// (or one item) this runs inline on the caller's thread. A panicking
    /// task propagates to the caller exactly as inline execution would
    /// (the worker's panic payload is resumed, not replaced) — callers
    /// that need to survive a panicking task catch it inside `f`.
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        // one body to maintain: drive the mutable fan-out over a vector
        // of shared references (&T is Send because T: Sync)
        let mut refs: Vec<&T> = items.iter().collect();
        self.map_mut(&mut refs, |i, it| f(i, *it))
    }

    /// [`Pool::map`] with mutable access to each item (the serve decode
    /// loop advances slots in place). Chunks are disjoint `&mut` splits,
    /// so no locking anywhere; the same panic policy as [`Pool::map`].
    pub fn map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        let n = items.len();
        let workers = self.threads.min(n);
        if workers <= 1 {
            return items.iter_mut().enumerate().map(|(i, it)| f(i, it)).collect();
        }
        let fref = &f;
        let chunked: Vec<Vec<R>> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(workers);
            let mut rest: &mut [T] = items;
            let mut start = 0usize;
            for w in 0..workers {
                let len = chunk_len(n, workers, w);
                // `mem::take` moves the slice out so the split halves keep
                // the full input lifetime (a plain reborrow would not
                // outlive this iteration)
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(len);
                rest = tail;
                let chunk_start = start;
                start += len;
                handles.push(scope.spawn(move || {
                    chunk
                        .iter_mut()
                        .enumerate()
                        .map(|(i, it)| fref(chunk_start + i, it))
                        .collect::<Vec<R>>()
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|payload| std::panic::resume_unwind(payload)))
                .collect()
        });
        chunked.into_iter().flatten().collect()
    }
}

impl Default for Pool {
    fn default() -> Self {
        Pool::from_env()
    }
}

/// Length of worker `w`'s contiguous chunk when splitting `n` items over
/// `workers` workers: sizes differ by at most one, larger chunks first.
fn chunk_len(n: usize, workers: usize, w: usize) -> usize {
    n / workers + usize::from(w < n % workers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_threads_clamps_to_one() {
        assert_eq!(Pool::new(0).threads(), 1);
        assert_eq!(Pool::new(3).threads(), 3);
    }

    #[test]
    fn chunks_cover_everything_once() {
        for n in [0usize, 1, 2, 5, 7, 16] {
            for workers in [1usize, 2, 3, 5, 8] {
                let total: usize = (0..workers).map(|w| chunk_len(n, workers, w)).sum();
                assert_eq!(total, n, "n={n} workers={workers}");
            }
        }
    }

    #[test]
    fn map_preserves_item_order() {
        let items: Vec<usize> = (0..23).collect();
        for threads in [1usize, 2, 4, 32] {
            let out = Pool::new(threads).map(&items, |i, &x| {
                assert_eq!(i, x, "index must match item position");
                x * 10
            });
            let want: Vec<usize> = (0..23).map(|x| x * 10).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn map_mut_mutates_in_place_and_returns_in_order() {
        for threads in [1usize, 2, 5] {
            let mut items: Vec<u64> = (0..9).collect();
            let out = Pool::new(threads).map_mut(&mut items, |i, x| {
                *x += 100;
                i as u64
            });
            assert_eq!(items, (100..109).collect::<Vec<u64>>(), "threads={threads}");
            assert_eq!(out, (0..9).collect::<Vec<u64>>(), "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single_item_inputs() {
        let pool = Pool::new(4);
        let empty: Vec<u32> = vec![];
        assert!(pool.map(&empty, |_, &x| x).is_empty());
        let mut one = vec![7u32];
        assert_eq!(pool.map_mut(&mut one, |_, x| *x * 2), vec![14]);
    }

    #[test]
    fn results_identical_across_thread_counts() {
        let items: Vec<u64> = (0..17).map(|i| i * 31 + 7).collect();
        let baseline = Pool::new(1).map(&items, |i, &x| x.wrapping_mul(i as u64 + 1));
        for threads in [2usize, 3, 8] {
            let got = Pool::new(threads).map(&items, |i, &x| x.wrapping_mul(i as u64 + 1));
            assert_eq!(got, baseline, "threads={threads}");
        }
    }

    #[test]
    fn env_threads_is_positive() {
        assert!(env_threads() >= 1);
    }
}
