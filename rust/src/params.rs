//! Canonical-order parameter store and checkpoint codec (S4).
//!
//! The [`ParamStore`] is *the* source of truth for model state on the
//! training path: PJRT artifacts receive its tensors positionally (the
//! canonical order of `config::param_specs`), the optimizer walks it in
//! lock-step, and the six expansion surgeries ([`crate::expand`]) consume
//! one store and produce the next stage's store.
//!
//! Checkpoints use a purpose-built binary format (no serde available):
//!
//! ```text
//! magic "TXPD" | u32 version | u64 header_len | header JSON | f32-LE data*
//! ```
//!
//! The JSON header carries the `ModelConfig`, the param specs (re-validated
//! on load), and caller metadata (step counts, RNG state, optimizer flags).

use std::collections::HashMap;
use std::io::{Read, Write};

use crate::config::{param_specs, ModelConfig, ParamSpec};
use crate::error::{Error, Result};
use crate::json::Value;
use crate::rng::Pcg32;
use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"TXPD";
const VERSION: u32 = 1;

/// Named parameter tensors in canonical order.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamStore {
    config: ModelConfig,
    specs: Vec<ParamSpec>,
    tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl ParamStore {
    /// Zero-initialized store for `config`.
    pub fn zeros(config: &ModelConfig) -> ParamStore {
        let specs = param_specs(config);
        let tensors = specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
        Self::assemble(*config, specs, tensors)
    }

    /// Random init matching `python/compile/model.py::init_params`:
    /// norm gains at 1, biases at 0, everything else `scale * N(0,1)`.
    pub fn init(config: &ModelConfig, rng: &mut Pcg32, scale: f32) -> ParamStore {
        let specs = param_specs(config);
        let tensors = specs
            .iter()
            .map(|s| {
                if s.name.ends_with("g_mha") || s.name.ends_with("g_mlp") {
                    Tensor::ones(&s.shape)
                } else if s.name.ends_with("b1") || s.name.ends_with("b2") {
                    Tensor::zeros(&s.shape)
                } else {
                    Tensor::randn(&s.shape, rng, scale)
                }
            })
            .collect();
        Self::assemble(*config, specs, tensors)
    }

    /// Build from an explicit name->tensor map (the expansion surgeries use
    /// this); every canonical param must be present with the right shape.
    pub fn from_map(config: &ModelConfig, mut map: HashMap<String, Tensor>) -> Result<ParamStore> {
        let specs = param_specs(config);
        let mut tensors = Vec::with_capacity(specs.len());
        for spec in &specs {
            let t = map
                .remove(&spec.name)
                .ok_or_else(|| Error::Params(format!("missing param '{}'", spec.name)))?;
            if t.shape() != spec.shape.as_slice() {
                return Err(Error::Params(format!(
                    "param '{}': expected shape {:?}, got {:?}",
                    spec.name,
                    spec.shape,
                    t.shape()
                )));
            }
            tensors.push(t);
        }
        if let Some(extra) = map.keys().next() {
            return Err(Error::Params(format!("unexpected param '{extra}' for config {config:?}")));
        }
        Ok(Self::assemble(*config, specs, tensors))
    }

    fn assemble(config: ModelConfig, specs: Vec<ParamSpec>, tensors: Vec<Tensor>) -> ParamStore {
        let index = specs.iter().enumerate().map(|(i, s)| (s.name.clone(), i)).collect();
        ParamStore { config, specs, tensors, index }
    }

    // ---- accessors ---------------------------------------------------------

    pub fn config(&self) -> &ModelConfig {
        &self.config
    }

    pub fn specs(&self) -> &[ParamSpec] {
        &self.specs
    }

    /// Number of parameter *tensors*.
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total scalar count.
    pub fn num_scalars(&self) -> usize {
        self.tensors.iter().map(Tensor::numel).sum()
    }

    /// Lookup by canonical name.
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.index
            .get(name)
            .map(|&i| &self.tensors[i])
            .ok_or_else(|| Error::Params(format!("no param named '{name}'")))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        match self.index.get(name) {
            Some(&i) => Ok(&mut self.tensors[i]),
            None => Err(Error::Params(format!("no param named '{name}'"))),
        }
    }

    /// Canonical-order iteration (the PJRT input order).
    pub fn iter(&self) -> impl Iterator<Item = (&ParamSpec, &Tensor)> {
        self.specs.iter().zip(self.tensors.iter())
    }

    /// Consume the store into a name->tensor map (no tensor copies) — the
    /// zero-copy entry to the expansion surgery (`expand::apply_ops_owned`).
    pub fn into_map(self) -> HashMap<String, Tensor> {
        self.specs.into_iter().map(|s| s.name).zip(self.tensors).collect()
    }

    /// Canonical-order tensor slice.
    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    /// Consume the store into its canonical-order tensor vector — the
    /// gradient-export path of the native autodiff backend (gradients are
    /// accumulated into a zeroed store so they inherit this order for free).
    pub fn into_tensors(self) -> Vec<Tensor> {
        self.tensors
    }

    /// Mutable canonical-order tensors (optimizer update path).
    pub fn tensors_mut(&mut self) -> &mut [Tensor] {
        &mut self.tensors
    }

    /// Move a tensor in by name (shape-checked).
    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        let i = *self
            .index
            .get(name)
            .ok_or_else(|| Error::Params(format!("no param named '{name}'")))?;
        if t.shape() != self.specs[i].shape.as_slice() {
            return Err(Error::Params(format!(
                "param '{name}': expected shape {:?}, got {:?}",
                self.specs[i].shape,
                t.shape()
            )));
        }
        self.tensors[i] = t;
        Ok(())
    }

    /// True if every scalar in every tensor is finite.
    pub fn all_finite(&self) -> bool {
        self.tensors.iter().all(Tensor::all_finite)
    }

    /// Largest |Δ| across all tensors against another store of identical
    /// layout (used by checkpoint tests and surgery no-op checks).
    pub fn max_abs_diff(&self, other: &ParamStore) -> Result<f32> {
        if self.config != other.config {
            return Err(Error::Params("max_abs_diff across different configs".into()));
        }
        let mut worst = 0.0f32;
        for (a, b) in self.tensors.iter().zip(&other.tensors) {
            worst = worst.max(a.max_abs_diff(b)?);
        }
        Ok(worst)
    }

    // ---- checkpoints ---------------------------------------------------------

    /// Serialize to `path` with caller metadata (any JSON value).
    pub fn save(&self, path: &str, meta: &Value) -> Result<()> {
        let header = Value::obj(vec![
            ("config", self.config.to_json()),
            (
                "params",
                Value::Arr(
                    self.specs
                        .iter()
                        .map(|s| {
                            Value::obj(vec![
                                ("name", Value::str(s.name.clone())),
                                ("shape", Value::Arr(s.shape.iter().map(|&d| Value::num(d as f64)).collect())),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("meta", meta.clone()),
        ]);
        let header_bytes = header.to_string().into_bytes();
        let mut file = std::fs::File::create(path).map_err(|e| Error::io(path, e))?;
        let mut buf = Vec::with_capacity(16 + header_bytes.len() + 4 * self.num_scalars());
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&(header_bytes.len() as u64).to_le_bytes());
        buf.extend_from_slice(&header_bytes);
        for t in &self.tensors {
            for x in t.data() {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        file.write_all(&buf).map_err(|e| Error::io(path, e))?;
        Ok(())
    }

    /// Load a checkpoint; returns the store and the caller metadata.
    pub fn load(path: &str) -> Result<(ParamStore, Value)> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .map_err(|e| Error::io(path, e))?
            .read_to_end(&mut bytes)
            .map_err(|e| Error::io(path, e))?;
        if bytes.len() < 16 || &bytes[0..4] != MAGIC {
            return Err(Error::Checkpoint(format!("{path}: not a texpand checkpoint")));
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(Error::Checkpoint(format!("{path}: unsupported version {version}")));
        }
        let header_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        if bytes.len() < 16 + header_len {
            return Err(Error::Checkpoint(format!("{path}: truncated header")));
        }
        let header_text = std::str::from_utf8(&bytes[16..16 + header_len])
            .map_err(|_| Error::Checkpoint(format!("{path}: header is not UTF-8")))?;
        let header = Value::parse(header_text)?;
        let config = ModelConfig::from_json(header.req("config")?)?;
        let specs = param_specs(&config);

        // Re-validate the stored spec list against our canonical layout:
        // a checkpoint from a diverged build must not load silently.
        let stored = header.req("params")?.as_arr()?;
        if stored.len() != specs.len() {
            return Err(Error::Checkpoint(format!(
                "{path}: {} params stored, config implies {}",
                stored.len(),
                specs.len()
            )));
        }
        for (s, spec) in stored.iter().zip(&specs) {
            let name = s.req("name")?.as_str()?;
            let shape: Vec<usize> =
                s.req("shape")?.as_arr()?.iter().map(|d| d.as_usize()).collect::<Result<_>>()?;
            if name != spec.name || shape != spec.shape {
                return Err(Error::Checkpoint(format!(
                    "{path}: param '{name}' {shape:?} does not match canonical '{}' {:?}",
                    spec.name, spec.shape
                )));
            }
        }

        let total_scalars: usize = specs.iter().map(|s| s.shape.iter().product::<usize>()).sum();
        let data = &bytes[16 + header_len..];
        if data.len() != 4 * total_scalars {
            return Err(Error::Checkpoint(format!(
                "{path}: payload {} bytes, expected {}",
                data.len(),
                4 * total_scalars
            )));
        }
        let mut tensors = Vec::with_capacity(specs.len());
        let mut off = 0usize;
        for spec in &specs {
            let n: usize = spec.shape.iter().product();
            let mut vals = Vec::with_capacity(n);
            for i in 0..n {
                let b = &data[off + 4 * i..off + 4 * i + 4];
                vals.push(f32::from_le_bytes(b.try_into().unwrap()));
            }
            off += 4 * n;
            tensors.push(Tensor::from_vec(&spec.shape, vals)?);
        }
        let meta = header.get("meta").cloned().unwrap_or(Value::Null);
        Ok((Self::assemble(config, specs, tensors), meta))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig { layers: 1, hidden: 8, heads: 2, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 }
    }

    #[test]
    fn zeros_and_init_shapes() {
        let s = ParamStore::zeros(&cfg());
        assert_eq!(s.len(), param_specs(&cfg()).len());
        assert_eq!(s.num_scalars(), cfg().num_params());
        let mut rng = Pcg32::seeded(0);
        let s = ParamStore::init(&cfg(), &mut rng, 0.02);
        assert_eq!(s.num_scalars(), cfg().num_params());
    }

    #[test]
    fn init_follows_python_conventions() {
        let mut rng = Pcg32::seeded(1);
        let s = ParamStore::init(&cfg(), &mut rng, 0.02);
        assert_eq!(s.get("layer_0.g_mha").unwrap().data(), &[1.0; 8]);
        assert_eq!(s.get("layer_0.b1").unwrap().data(), &[0.0; 16]);
        assert!(s.get("embed").unwrap().max_abs() > 0.0);
        assert!(s.get("embed").unwrap().max_abs() < 0.2);
    }

    #[test]
    fn get_set_roundtrip_and_errors() {
        let mut s = ParamStore::zeros(&cfg());
        assert!(s.get("nope").is_err());
        assert!(s.get_mut("nope").is_err());
        let t = Tensor::ones(&[8, 4]);
        s.set("layer_0.head_0.wq", t.clone()).unwrap();
        assert_eq!(s.get("layer_0.head_0.wq").unwrap(), &t);
        assert!(s.set("layer_0.head_0.wq", Tensor::ones(&[4, 8])).is_err());
        assert!(s.set("nope", Tensor::ones(&[1])).is_err());
    }

    #[test]
    fn from_map_validates() {
        let full: HashMap<String, Tensor> =
            ParamStore::zeros(&cfg()).iter().map(|(s, t)| (s.name.clone(), t.clone())).collect();
        assert!(ParamStore::from_map(&cfg(), full.clone()).is_ok());

        let mut missing = full.clone();
        missing.remove("pos");
        assert!(ParamStore::from_map(&cfg(), missing).is_err());

        let mut extra = full.clone();
        extra.insert("bogus".into(), Tensor::ones(&[1]));
        assert!(ParamStore::from_map(&cfg(), extra).is_err());

        let mut wrong = full;
        wrong.insert("pos".into(), Tensor::ones(&[1, 1]));
        assert!(ParamStore::from_map(&cfg(), wrong).is_err());
    }

    #[test]
    fn iteration_is_canonical_order() {
        let s = ParamStore::zeros(&cfg());
        let names: Vec<&str> = s.iter().map(|(spec, _)| spec.name.as_str()).collect();
        let want: Vec<String> = param_specs(&cfg()).into_iter().map(|s| s.name).collect();
        assert_eq!(names, want.iter().map(String::as_str).collect::<Vec<_>>());
    }

    #[test]
    fn finiteness_and_diff() {
        let mut rng = Pcg32::seeded(2);
        let a = ParamStore::init(&cfg(), &mut rng, 0.1);
        let mut b = a.clone();
        assert!(a.all_finite());
        assert_eq!(a.max_abs_diff(&b).unwrap(), 0.0);
        b.get_mut("w_out").unwrap().data_mut()[0] += 0.5;
        assert!((a.max_abs_diff(&b).unwrap() - 0.5).abs() < 1e-6);
        b.get_mut("w_out").unwrap().data_mut()[1] = f32::NAN;
        assert!(!b.all_finite());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join(format!("texpand-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.txpd");
        let path = path.to_str().unwrap();

        let mut rng = Pcg32::seeded(3);
        let orig = ParamStore::init(&cfg(), &mut rng, 0.05);
        let meta = Value::parse(r#"{"step": 42, "stage": "stage1"}"#).unwrap();
        orig.save(path, &meta).unwrap();
        let (loaded, got_meta) = ParamStore::load(path).unwrap();
        assert_eq!(loaded.config(), orig.config());
        assert_eq!(orig.max_abs_diff(&loaded).unwrap(), 0.0);
        assert_eq!(got_meta.req("step").unwrap().as_i64().unwrap(), 42);
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn checkpoint_rejects_corruption() {
        let dir = std::env::temp_dir().join(format!("texpand-test-corrupt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.txpd");
        let spath = path.to_str().unwrap();

        // not a checkpoint at all
        std::fs::write(&path, b"hello world").unwrap();
        assert!(ParamStore::load(spath).is_err());

        // valid checkpoint, truncated payload
        let mut rng = Pcg32::seeded(4);
        let s = ParamStore::init(&cfg(), &mut rng, 0.05);
        s.save(spath, &Value::Null).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 8]).unwrap();
        let err = ParamStore::load(spath).unwrap_err().to_string();
        assert!(err.contains("payload"), "{err}");

        // bad magic
        let mut broken = bytes.clone();
        broken[0] = b'X';
        std::fs::write(&path, &broken).unwrap();
        assert!(ParamStore::load(spath).is_err());

        // bad version
        let mut broken = bytes;
        broken[4] = 99;
        std::fs::write(&path, &broken).unwrap();
        assert!(ParamStore::load(spath).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
