//! Miniature property-testing harness (S13; no `proptest` offline).
//!
//! A [`Runner`] drives N randomized cases through a property. On failure it
//! re-runs a bounded "shrink-lite" pass: the generator is re-invoked with
//! fresh entropy and the *smallest failing case by the caller's size metric*
//! is reported. This trades proptest's integrated shrinking for ~100 lines
//! of dependency-free code — adequate for our invariants, which are mostly
//! over small config tuples and op sequences.
//!
//! ```no_run
//! # // no_run: rustdoc test binaries lack the libxla rpath this crate links with
//! use texpand::prop::Runner;
//! Runner::new("sum-commutes", 64).run(
//!     |rng| (rng.range(-100, 100), rng.range(-100, 100)),
//!     |&(a, b)| {
//!         if a + b == b + a { Ok(()) } else { Err(format!("{a}+{b} not commutative")) }
//!     },
//! );
//! ```

use crate::rng::Pcg32;

/// Property-test driver. Panics (with the smallest found counterexample)
/// when the property fails.
pub struct Runner {
    name: String,
    cases: usize,
    seed: u64,
    shrink_budget: usize,
}

impl Runner {
    /// A runner executing `cases` random cases under a fixed default seed
    /// (tests are deterministic; override with [`Runner::seed`]).
    pub fn new(name: impl Into<String>, cases: usize) -> Runner {
        Runner { name: name.into(), cases, seed: 0xC0FFEE, shrink_budget: 200 }
    }

    /// Override the base seed.
    pub fn seed(mut self, seed: u64) -> Runner {
        self.seed = seed;
        self
    }

    /// Override the number of extra candidates examined after a failure.
    pub fn shrink_budget(mut self, budget: usize) -> Runner {
        self.shrink_budget = budget;
        self
    }

    /// Run `prop` over `cases` values drawn from `gen`.
    pub fn run<T: std::fmt::Debug>(
        &self,
        mut gen: impl FnMut(&mut Pcg32) -> T,
        mut prop: impl FnMut(&T) -> Result<(), String>,
    ) {
        self.run_sized(&mut gen, |_| 0usize, &mut prop)
    }

    /// Like [`Runner::run`] but with a size metric used to pick the
    /// *smallest* failing case among `shrink_budget` re-draws.
    pub fn run_sized<T: std::fmt::Debug>(
        &self,
        gen: &mut impl FnMut(&mut Pcg32) -> T,
        size: impl Fn(&T) -> usize,
        prop: &mut impl FnMut(&T) -> Result<(), String>,
    ) {
        let mut rng = Pcg32::new(self.seed, 17);
        for case in 0..self.cases {
            let value = gen(&mut rng);
            if let Err(msg) = prop(&value) {
                // shrink-lite: sample more cases, keep the smallest failure
                let mut best = (size(&value), value, msg);
                for _ in 0..self.shrink_budget {
                    let cand = gen(&mut rng);
                    let s = size(&cand);
                    if s < best.0 {
                        if let Err(m) = prop(&cand) {
                            best = (s, cand, m);
                        }
                    }
                }
                panic!(
                    "property '{}' failed at case {case}/{}:\n  counterexample (size {}): {:?}\n  reason: {}",
                    self.name, self.cases, best.0, best.1, best.2
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        Runner::new("abs-nonneg", 200).run(
            |rng| rng.range(-1000, 1000),
            |&x| if x.abs() >= 0 { Ok(()) } else { Err("negative abs".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_name() {
        Runner::new("always-false", 10).run(|rng| rng.below(5), |_| Err("nope".into()));
    }

    #[test]
    fn shrink_reports_smaller_case() {
        // property fails for any x >= 10; the shrink pass should land on a
        // case well below the first random failure's typical magnitude.
        let result = std::panic::catch_unwind(|| {
            Runner::new("ge-ten", 100).shrink_budget(500).run_sized(
                &mut |rng| rng.below(1000),
                |&x| x,
                &mut |&x| if x < 10 { Ok(()) } else { Err("too big".into()) },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // extract the reported size
        let size: usize = msg
            .split("(size ")
            .nth(1)
            .and_then(|s| s.split(')').next())
            .and_then(|s| s.parse().ok())
            .unwrap();
        assert!(size < 100, "shrink-lite should find a smallish case, got {size}: {msg}");
    }

    #[test]
    fn deterministic_under_seed() {
        let collect = |seed: u64| {
            let mut out = Vec::new();
            Runner::new("collect", 5).seed(seed).run(
                |rng| rng.next_u32(),
                |&x| {
                    // abuse the property to observe the stream
                    let _ = x;
                    Ok(())
                },
            );
            let mut rng = Pcg32::new(seed, 17);
            for _ in 0..5 {
                out.push(rng.next_u32());
            }
            out
        };
        assert_eq!(collect(1), collect(1));
        assert_ne!(collect(1), collect(2));
    }
}
