//! Minimal JSON parser + serializer (substrate S1).
//!
//! The offline crate set has no `serde`/`serde_json`, and the framework
//! speaks JSON at three boundaries: `artifacts/manifest.json` (written by
//! the Python AOT step), growth-schedule configs (shared with Python), and
//! JSONL metrics output. This module implements the subset of RFC 8259 we
//! need: full syntax, `\uXXXX` escapes (including surrogate pairs), numbers
//! as `f64`, objects as *order-preserving* key/value vectors.
//!
//! Not supported (by design): duplicate-key detection is last-wins,
//! numbers beyond f64 precision, and non-UTF8 input.

use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Order-preserving object (manifests are human-diffed; order matters).
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Parse the file at `path`.
    pub fn load(path: &str) -> Result<Value> {
        let text = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
        Value::parse(&text).map_err(|e| Error::Json(format!("{path}: {e}")))
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(Error::Json(format!("expected bool, got {}", self.kind()))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(Error::Json(format!("expected number, got {}", self.kind()))),
        }
    }

    /// Integer accessor; rejects non-integral numbers.
    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 || !n.is_finite() || n.abs() > 2f64.powi(53) {
            return Err(Error::Json(format!("expected integer, got {n}")));
        }
        Ok(n as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_i64()?;
        usize::try_from(n).map_err(|_| Error::Json(format!("expected non-negative integer, got {n}")))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(Error::Json(format!("expected string, got {}", self.kind()))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => Err(Error::Json(format!("expected array, got {}", self.kind()))),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Ok(o),
            _ => Err(Error::Json(format!("expected object, got {}", self.kind()))),
        }
    }

    /// Object field lookup (last-wins on duplicates).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(o) => o.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required object field, with a path-style error.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| Error::Json(format!("missing required field '{key}'")))
    }

    fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Arr(_) => "array",
            Value::Obj(_) => "object",
        }
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(fields: Vec<(&str, Value)>) -> Value {
        Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    // ---- serialization ---------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_number(out, *n),
            Value::Str(s) => write_string(out, s),
            Value::Arr(a) => write_seq(out, indent, depth, '[', ']', a.len(), |out, i, ind, d| {
                a[i].write(out, ind, d);
            }),
            Value::Obj(o) => write_seq(out, indent, depth, '{', '}', o.len(), |out, i, ind, d| {
                write_string(out, &o[i].0);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                o[i].1.write(out, ind, d);
            }),
        }
    }
}

fn write_number(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null (metrics sink tolerates it).
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_seq(
    out: &mut String,
    indent: Option<usize>,
    depth: usize,
    open: char,
    close: char,
    len: usize,
    mut item: impl FnMut(&mut String, usize, Option<usize>, usize),
) {
    out.push(open);
    if len == 0 {
        out.push(close);
        return;
    }
    for i in 0..len {
        if i > 0 {
            out.push(',');
        }
        if let Some(w) = indent {
            out.push('\n');
            out.push_str(&" ".repeat(w * (depth + 1)));
        }
        item(out, i, indent, depth + 1);
    }
    if let Some(w) = indent {
        out.push('\n');
        out.push_str(&" ".repeat(w * depth));
    }
    out.push(close);
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        let (mut line, mut col) = (1usize, 1usize);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Error::Json(format!("{msg} at line {line}, col {col}"))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected '{word}')")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                // high surrogate: require a low surrogate next
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    let combined = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined).ok_or_else(|| self.err("invalid surrogate pair"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else if (0xDC00..0xE000).contains(&cp) {
                                return Err(self.err("lone low surrogate"));
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("invalid \\u escape"))?
                            };
                            out.push(c);
                            continue; // hex4 advanced pos past the digits
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated UTF-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end]).map_err(|_| self.err("invalid UTF-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut cp = 0u32;
        for _ in 0..4 {
            let d = match self.peek() {
                Some(c @ b'0'..=b'9') => (c - b'0') as u32,
                Some(c @ b'a'..=b'f') => (c - b'a' + 10) as u32,
                Some(c @ b'A'..=b'F') => (c - b'A' + 10) as u32,
                _ => return Err(self.err("invalid hex digit in \\u escape")),
            };
            cp = cp * 16 + d;
            self.pos += 1;
        }
        Ok(cp)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // int part
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit required after decimal point"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit required in exponent"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Num).map_err(|_| self.err("number out of range"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("false").unwrap(), Value::Bool(false));
        assert_eq!(Value::parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(Value::parse("-3.25e2").unwrap(), Value::Num(-325.0));
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, {"b": null}, "x"], "c": {"d": false}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap(), &Value::Bool(false));
    }

    #[test]
    fn preserves_key_order() {
        let v = Value::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn escapes_roundtrip() {
        let orig = Value::Str("line\nquote\"back\\slash\ttab\u{0001}".into());
        let text = orig.to_string();
        assert_eq!(Value::parse(&text).unwrap(), orig);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Value::parse(r#""é""#).unwrap(), Value::Str("é".into()));
        // surrogate pair: U+1F600
        assert_eq!(Value::parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
        assert!(Value::parse(r#""\ud83d""#).is_err()); // lone high surrogate
        assert!(Value::parse(r#""\ude00""#).is_err()); // lone low surrogate
    }

    #[test]
    fn utf8_passthrough() {
        let v = Value::parse("\"héllo 😀\"").unwrap();
        assert_eq!(v, Value::Str("héllo 😀".into()));
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "{\"a\":}", "01", "1.", "1e", "tru", "\"\\q\"", "[1] extra",
            "nan", "+1", "'single'",
        ] {
            assert!(Value::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn error_reports_position() {
        let err = Value::parse("{\n  \"a\": bad\n}").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn integer_accessors() {
        assert_eq!(Value::parse("7").unwrap().as_i64().unwrap(), 7);
        assert_eq!(Value::parse("7").unwrap().as_usize().unwrap(), 7);
        assert!(Value::parse("7.5").unwrap().as_i64().is_err());
        assert!(Value::parse("-7").unwrap().as_usize().is_err());
        assert!(Value::parse("\"7\"").unwrap().as_i64().is_err());
    }

    #[test]
    fn req_reports_missing_field() {
        let v = Value::parse(r#"{"a":1}"#).unwrap();
        assert!(v.req("a").is_ok());
        let err = v.req("b").unwrap_err().to_string();
        assert!(err.contains("'b'"), "{err}");
    }

    #[test]
    fn pretty_printing_is_reparseable() {
        let v = Value::parse(r#"{"a":[1,2,{"b":"c"}],"d":{}}"#).unwrap();
        let pretty = v.to_pretty();
        assert!(pretty.contains("\n  "));
        assert_eq!(Value::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn compact_numbers_stay_integral() {
        assert_eq!(Value::Num(5.0).to_string(), "5");
        assert_eq!(Value::Num(5.5).to_string(), "5.5");
        assert_eq!(Value::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn roundtrip_fuzz_light() {
        // quick structural fuzz: build random values, serialize, reparse.
        use crate::rng::Pcg32;
        fn gen(r: &mut Pcg32, depth: usize) -> Value {
            match if depth == 0 { r.below(4) } else { r.below(6) } {
                0 => Value::Null,
                1 => Value::Bool(r.below(2) == 0),
                2 => Value::Num((r.range(-1000, 1000) as f64) / 8.0),
                3 => Value::Str(format!("s{}-\"\\\n", r.below(100))),
                4 => Value::Arr((0..r.below(4)).map(|_| gen(r, depth - 1)).collect()),
                _ => Value::Obj((0..r.below(4)).map(|i| (format!("k{i}"), gen(r, depth - 1))).collect()),
            }
        }
        let mut r = Pcg32::seeded(99);
        for _ in 0..200 {
            let v = gen(&mut r, 3);
            assert_eq!(Value::parse(&v.to_string()).unwrap(), v);
            assert_eq!(Value::parse(&v.to_pretty()).unwrap(), v);
        }
    }
}
