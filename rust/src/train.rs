//! Single-stage training loop (S10a).
//!
//! One stage = one architecture = one `step` executable. The loop is the
//! L3 hot path: batch synthesis → backend step (PJRT artifact or native
//! autodiff) → gradient clip → optimizer update → metrics. It is written
//! against [`ExecBackend`], so the same loop drives both engines; Python
//! is never involved.

use crate::autodiff::ExecBackend;
use crate::config::TrainConfig;
use crate::data::Batcher;
use crate::error::{Error, Result};
use crate::json::Value;
use crate::metrics::{RunLogger, Timer};
use crate::optim::{clip_global_norm, Optimizer};
use crate::params::ParamStore;
use crate::runtime::StageExec;

/// Outcome of one stage's training.
#[derive(Clone, Debug)]
pub struct StageReport {
    pub stage: String,
    pub steps_run: usize,
    pub first_loss: f32,
    pub final_loss: f32,
    /// Mean loss over the last `min(10, steps)` steps (less noisy).
    pub tail_mean_loss: f32,
    pub tokens_per_sec: f64,
    pub step_ms_mean: f64,
}

/// Mutable cross-stage training state threaded through the coordinator.
pub struct TrainState {
    pub global_step: usize,
    pub tokens_seen: usize,
}

impl TrainState {
    pub fn new() -> TrainState {
        TrainState { global_step: 0, tokens_seen: 0 }
    }
}

impl Default for TrainState {
    fn default() -> Self {
        Self::new()
    }
}

/// Train `steps` steps of one stage. Fails fast on non-finite loss (the
/// preservation property makes boundary loss spikes a bug, not a hazard
/// of the method).
#[allow(clippy::too_many_arguments)]
pub fn train_stage(
    backend: &dyn ExecBackend,
    stage: &StageExec,
    params: &mut ParamStore,
    opt: &mut Optimizer,
    batcher: &mut Batcher,
    tcfg: &TrainConfig,
    logger: &mut RunLogger,
    state: &mut TrainState,
    steps: usize,
) -> Result<StageReport> {
    if steps == 0 {
        return Err(Error::Train(format!("stage '{}' scheduled for 0 steps", stage.meta.name)));
    }
    opt.validate_against(params)?;
    let tokens_per_step = stage.batch * stage.meta.config.seq;
    let timer = Timer::start();
    let mut first_loss = f32::NAN;
    let mut last_losses: Vec<f32> = Vec::new();
    let mut step_ms_total = 0.0f64;

    for local_step in 0..steps {
        let batch = batcher.next();
        let step_timer = Timer::start();
        let (loss, mut grads) = backend.step(stage, params, &batch)?;
        if !loss.is_finite() {
            return Err(Error::Train(format!(
                "non-finite loss {loss} at stage '{}' step {local_step}",
                stage.meta.name
            )));
        }
        let grad_norm = match tcfg.grad_clip {
            Some(max) => clip_global_norm(&mut grads, max),
            None => f32::NAN,
        };
        opt.step(params, &grads)?;
        step_ms_total += step_timer.ms();

        if local_step == 0 {
            first_loss = loss;
        }
        last_losses.push(loss);
        if last_losses.len() > 10 {
            last_losses.remove(0);
        }
        state.global_step += 1;
        state.tokens_seen += tokens_per_step;
        logger.loss_row(state.global_step, &stage.meta.name, loss, state.tokens_seen);
        if local_step % tcfg.log_every == 0 || local_step + 1 == steps {
            logger.event(
                "step",
                vec![
                    ("stage", Value::str(stage.meta.name.clone())),
                    ("global_step", Value::num(state.global_step as f64)),
                    ("local_step", Value::num(local_step as f64)),
                    ("loss", Value::num(f64::from(loss))),
                    ("grad_norm", Value::num(f64::from(grad_norm))),
                ],
            );
        }
    }

    let final_loss = *last_losses.last().unwrap();
    let tail_mean_loss = last_losses.iter().sum::<f32>() / last_losses.len() as f32;
    let report = StageReport {
        stage: stage.meta.name.clone(),
        steps_run: steps,
        first_loss,
        final_loss,
        tail_mean_loss,
        tokens_per_sec: (steps * tokens_per_step) as f64 / timer.secs(),
        step_ms_mean: step_ms_total / steps as f64,
    };
    logger.event(
        "stage_done",
        vec![
            ("stage", Value::str(report.stage.clone())),
            ("steps", Value::num(report.steps_run as f64)),
            ("first_loss", Value::num(f64::from(report.first_loss))),
            ("final_loss", Value::num(f64::from(report.final_loss))),
            ("tail_mean_loss", Value::num(f64::from(report.tail_mean_loss))),
            ("tokens_per_sec", Value::num(report.tokens_per_sec)),
            ("step_ms_mean", Value::num(report.step_ms_mean)),
            ("params", Value::num(params.num_scalars() as f64)),
        ],
    );
    Ok(report)
}

/// Evaluate mean loss on a fixed probe batch via the backend's fwd path.
pub fn eval_loss(
    backend: &dyn ExecBackend,
    stage: &StageExec,
    params: &ParamStore,
    batch: &crate::data::Batch,
) -> Result<f32> {
    let logits = backend.forward(stage, params, &batch.tokens)?;
    crate::model::cross_entropy(&logits, &batch.targets)
}
