//! Single-segment training loop (S10a) + the policy observation stream.
//!
//! One segment = one architecture = one `step` executable. The loop is the
//! L3 hot path: batch synthesis → backend step (PJRT artifact or native
//! autodiff) → gradient clip → optimizer update → metrics. It is written
//! against [`ExecBackend`], so the same loop drives both engines; Python
//! is never involved.
//!
//! Two entry points share the inner loop:
//! * [`train_segment`] — policy-driven: after every optimizer update a
//!   [`TrainObs`] (step, losses, tokens, estimated FLOPs) is handed to a
//!   [`GrowthPolicy`], whose [`Decision`] ends the segment (expand/stop)
//!   or lets it continue. Eval losses are probed on a fixed held-out batch
//!   only at the cadence the policy asks for — a pure forward pass, so
//!   observation never perturbs the training trajectory.
//! * [`train_stage`] — the classic fixed-step-count loop, expressed as a
//!   segment driven by an internal step-budget shim. Identical numerics to
//!   the pre-policy implementation.

use crate::autodiff::ExecBackend;
use crate::config::TrainConfig;
use crate::data::{Batch, Batcher};
use crate::error::{Error, Result};
use crate::growth::{Decision, GrowthPolicy, PolicyCtx, TrainObs};
use crate::json::Value;
use crate::metrics::{RunLogger, Timer};
use crate::optim::{clip_global_norm, Optimizer};
use crate::params::ParamStore;
use crate::runtime::StageExec;

/// Outcome of one segment's training.
#[derive(Clone, Debug)]
pub struct StageReport {
    pub stage: String,
    pub steps_run: usize,
    pub first_loss: f32,
    pub final_loss: f32,
    /// Mean loss over the last `min(10, steps)` steps (less noisy).
    pub tail_mean_loss: f32,
    pub tokens_per_sec: f64,
    pub step_ms_mean: f64,
    /// Scalar parameter count of the architecture this segment trained —
    /// segments are no longer pinned to schedule stages, so compute
    /// accounting (steps × params × tokens) needs it recorded per segment.
    pub params: usize,
}

/// Mutable cross-segment training state threaded through the coordinator.
pub struct TrainState {
    pub global_step: usize,
    pub tokens_seen: usize,
    /// Cumulative estimated training FLOPs (6·params·tokens per step),
    /// the evidence stream policies judge compute efficiency against.
    pub est_flops: f64,
}

impl TrainState {
    pub fn new() -> TrainState {
        TrainState { global_step: 0, tokens_seen: 0, est_flops: 0.0 }
    }
}

impl Default for TrainState {
    fn default() -> Self {
        Self::new()
    }
}

/// Why a policy-driven segment ended.
#[derive(Clone, Debug, PartialEq)]
pub enum SegmentEnd {
    /// The policy asked for an expansion boundary with this plan (an
    /// identity plan = split the segment without surgery).
    Expand(crate::expand::ExpansionPlan),
    /// The policy ended the run.
    Stop,
}

/// Internal shim making [`train_stage`] a degenerate policy-driven
/// segment: stop after exactly `steps` steps, no eval probes, no decision
/// logging. Keeping ONE inner loop is what guarantees the fixed-policy
/// coordinator stays bit-identical to plain staged training.
struct StepBudget {
    steps: usize,
}

impl GrowthPolicy for StepBudget {
    fn name(&self) -> &'static str {
        "steps"
    }

    fn log_decisions(&self) -> bool {
        false
    }

    fn decide(&mut self, obs: &TrainObs, _ctx: &PolicyCtx<'_>) -> Decision {
        if obs.arch_step >= self.steps {
            Decision::Stop
        } else {
            Decision::Continue
        }
    }
}

fn log_step_event(
    logger: &mut RunLogger,
    stage: &str,
    global_step: usize,
    local_step: usize,
    loss: f32,
    grad_norm: f32,
) {
    logger.event(
        "step",
        vec![
            ("stage", Value::str(stage)),
            ("global_step", Value::num(global_step as f64)),
            ("local_step", Value::num(local_step as f64)),
            ("loss", Value::num(f64::from(loss))),
            ("grad_norm", Value::num(f64::from(grad_norm))),
        ],
    );
}

/// Train one segment under `policy` control. Returns the segment report
/// and the decision that ended it. `probe` is the fixed held-out batch
/// eval observations are measured on (`None` = the policy gets no eval
/// signal even if it asks). Fails fast on non-finite loss (the
/// preservation property makes boundary loss spikes a bug, not a hazard
/// of the method).
///
/// `ckpt` is the durable-run attachment point (DESIGN.md §16): when
/// present, the loop asks it to write an interval checkpoint after each
/// fully applied optimizer step, and starts its local step counter from
/// the hook's pending resume offset so a resumed segment re-enters the
/// loop exactly where the checkpointed one left off.
#[allow(clippy::too_many_arguments)]
pub fn train_segment(
    backend: &dyn ExecBackend,
    stage: &StageExec,
    params: &mut ParamStore,
    opt: &mut Optimizer,
    batcher: &mut Batcher,
    tcfg: &TrainConfig,
    logger: &mut RunLogger,
    state: &mut TrainState,
    policy: &mut dyn GrowthPolicy,
    probe: Option<&Batch>,
    mut ckpt: Option<&mut crate::ckpt::CkptHook>,
) -> Result<(StageReport, SegmentEnd)> {
    opt.validate_against(params)?;
    let tokens_per_step = stage.batch * stage.meta.config.seq;
    let timer = Timer::start();
    let mut first_loss = f32::NAN;
    let mut last_losses: Vec<f32> = Vec::new();
    let mut step_ms_total = 0.0f64;
    let num_params = params.num_scalars();
    let mut last_step_event = (0usize, f32::NAN, f32::NAN);

    // live training gauges: same registry the serve path publishes
    // through, so one /metrics scrape covers either mode
    let reg = crate::obs::global();
    let step_gauge = reg.gauge("texpand_train_step", "Global optimizer step");
    let loss_gauge = reg.gauge("texpand_train_loss", "Latest training loss");
    let tps_gauge = reg.gauge("texpand_train_tokens_per_sec", "Latest step throughput");
    let params_gauge = reg.gauge("texpand_train_params", "Scalar parameter count");
    let tokens_counter = reg.counter("texpand_train_tokens_total", "Training tokens consumed");
    let eval_gauge = reg.gauge("texpand_train_eval_loss", "Latest held-out probe loss");
    params_gauge.set(num_params as f64);

    // a resumed segment continues its local step count; the enclosing
    // run's global counters arrive already-restored in `state`
    let mut local_step = match ckpt.as_deref_mut() {
        Some(h) => h.take_resume_local_step(),
        None => 0,
    };
    let end = loop {
        // crash-injection site for the recovery tests: "the process died
        // between two optimizer steps"
        crate::faults::fault_point("train_step");
        let batch = batcher.next();
        let step_timer = Timer::start();
        let (loss, mut grads) = backend.step(stage, params, &batch)?;
        if !loss.is_finite() {
            return Err(Error::Train(format!(
                "non-finite loss {loss} at stage '{}' step {local_step}",
                stage.meta.name
            )));
        }
        let grad_norm = match tcfg.grad_clip {
            Some(max) => clip_global_norm(&mut grads, max),
            None => f32::NAN,
        };
        opt.step(params, &grads)?;
        let step_ms = step_timer.ms();
        step_ms_total += step_ms;

        if last_losses.is_empty() {
            first_loss = loss;
        }
        last_losses.push(loss);
        if last_losses.len() > 10 {
            last_losses.remove(0);
        }
        state.global_step += 1;
        state.tokens_seen += tokens_per_step;
        state.est_flops += 6.0 * num_params as f64 * tokens_per_step as f64;
        step_gauge.set(state.global_step as f64);
        loss_gauge.set(f64::from(loss));
        if step_ms > 0.0 {
            tps_gauge.set(tokens_per_step as f64 / (step_ms / 1e3));
        }
        tokens_counter.add(tokens_per_step as u64);
        logger.loss_row(state.global_step, &stage.meta.name, loss, state.tokens_seen);
        last_step_event = (local_step, loss, grad_norm);
        if local_step % tcfg.log_every == 0 {
            log_step_event(logger, &stage.meta.name, state.global_step, local_step, loss, grad_norm);
        }

        // --- observe & decide -------------------------------------------
        let arch_step = local_step + 1;
        let eval_loss = match (policy.eval_every(), probe) {
            (Some(k), Some(p)) if arch_step % k == 0 => {
                let e = eval_loss(backend, stage, params, p)?;
                eval_gauge.set(f64::from(e));
                Some(e)
            }
            _ => None,
        };
        let obs = TrainObs {
            global_step: state.global_step,
            arch_step,
            train_loss: loss,
            eval_loss,
            tokens_seen: state.tokens_seen,
            est_flops: state.est_flops,
            params: num_params,
        };
        let ctx = PolicyCtx { params: &*params, opt: &*opt, batcher: &*batcher, tcfg };
        let decision = policy.decide(&obs, &ctx);
        if policy.log_decisions() && (obs.eval_loss.is_some() || decision != Decision::Continue) {
            logger.decision(policy.name(), &obs, &decision);
        }
        local_step += 1;
        match decision {
            Decision::Continue => {
                // interval checkpoint only on continuing steps: segment
                // ends get a forced boundary write from the coordinator,
                // which also knows the post-surgery state to capture
                if let Some(h) = ckpt.as_deref_mut() {
                    h.maybe_write(local_step, params, opt, batcher, &*policy, state, logger)?;
                }
            }
            Decision::Expand(plan) => break SegmentEnd::Expand(plan),
            Decision::Stop => break SegmentEnd::Stop,
        }
    };

    let steps = local_step;
    // the segment's last step always gets a `step` event (the fixed-count
    // loop logged `local_step + 1 == steps`; a policy-driven segment only
    // knows its last step after the fact)
    let (ls, loss, gn) = last_step_event;
    if ls % tcfg.log_every != 0 {
        log_step_event(logger, &stage.meta.name, state.global_step, ls, loss, gn);
    }
    let final_loss = *last_losses.last().expect("at least one step ran");
    let tail_mean_loss = last_losses.iter().sum::<f32>() / last_losses.len() as f32;
    let report = StageReport {
        stage: stage.meta.name.clone(),
        steps_run: steps,
        first_loss,
        final_loss,
        tail_mean_loss,
        tokens_per_sec: (steps * tokens_per_step) as f64 / timer.secs(),
        step_ms_mean: step_ms_total / steps as f64,
        params: num_params,
    };
    logger.event(
        "stage_done",
        vec![
            ("stage", Value::str(report.stage.clone())),
            ("steps", Value::num(report.steps_run as f64)),
            ("first_loss", Value::num(f64::from(report.first_loss))),
            ("final_loss", Value::num(f64::from(report.final_loss))),
            ("tail_mean_loss", Value::num(f64::from(report.tail_mean_loss))),
            ("tokens_per_sec", Value::num(report.tokens_per_sec)),
            ("step_ms_mean", Value::num(report.step_ms_mean)),
            ("params", Value::num(num_params as f64)),
        ],
    );
    // segment boundary: buffered log lines hit disk before surgery/eval
    logger.flush();
    Ok((report, end))
}

/// Train exactly `steps` steps of one stage (the non-policy entry point:
/// branch finetuning, probe training, benches).
#[allow(clippy::too_many_arguments)]
pub fn train_stage(
    backend: &dyn ExecBackend,
    stage: &StageExec,
    params: &mut ParamStore,
    opt: &mut Optimizer,
    batcher: &mut Batcher,
    tcfg: &TrainConfig,
    logger: &mut RunLogger,
    state: &mut TrainState,
    steps: usize,
) -> Result<StageReport> {
    if steps == 0 {
        return Err(Error::Train(format!("stage '{}' scheduled for 0 steps", stage.meta.name)));
    }
    let mut shim = StepBudget { steps };
    let (report, end) = train_segment(
        backend, stage, params, opt, batcher, tcfg, logger, state, &mut shim, None, None,
    )?;
    debug_assert_eq!(end, SegmentEnd::Stop);
    Ok(report)
}

/// Evaluate mean loss on a fixed probe batch via the backend's fwd path.
pub fn eval_loss(
    backend: &dyn ExecBackend,
    stage: &StageExec,
    params: &ParamStore,
    batch: &crate::data::Batch,
) -> Result<f32> {
    let logits = backend.forward(stage, params, &batch.tokens)?;
    crate::model::cross_entropy(&logits, &batch.targets)
}
