//! Pure-Rust reference transformer forward pass (S5; paper Eqs. 1–5).
//!
//! This is the PJRT-independent oracle: the growth coordinator uses it to
//! assert function preservation at expansion boundaries without trusting
//! the AOT path, and integration tests use it to validate that the HLO
//! artifacts compute the same function as this implementation (three-way
//! agreement: JAX == Rust == PJRT).
//!
//! Numerics mirror `python/compile/model.py`: RMSNorm with **no
//! epsilon** (Eq. 5 — required for Thm 3.5's exact norm scaling), additive
//! causal mask of `-1e30` applied *after* the `1/sqrt(k)` score scaling,
//! and max-stabilized softmax — computed by the online single-sweep pass
//! ([`crate::tensor::softmax_rows_online`]), which stays within 1e-6 of
//! the two-pass reference. Summation order differs from XLA's fused
//! loops anyway, so cross-implementation agreement is ~1e-5, not
//! bit-exact (tolerance policy: DESIGN.md §8). The raw-speed tier
//! (DESIGN.md §17) routes the hot products through the fused kernels —
//! `rmsnorm_matmul` in [`layer_tail`]'s Norm→W1 edge (bit-identical to
//! the unfused pair) and `attn_pv` for `probs · V` — so every forward
//! bit-identity guarantee (taped == reference, incremental == full)
//! is unchanged.

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::params::ParamStore;
use crate::serve::kv::{KvCacheImpl, KvStorage};
use crate::tensor::{rmsnorm_row, softmax_rows_online, Tensor};

/// Additive mask value for non-causal positions (matches kernels/ref.py).
pub const MASK_VALUE: f32 = -1e30;

/// RMSNorm (Eq. 5): `x_ij * g_j / sqrt(mean_j x_ij^2)` over a `[s, h]` tile.
pub fn rmsnorm(x: &Tensor, g: &Tensor) -> Result<Tensor> {
    if x.rank() != 2 || g.rank() != 1 || g.shape()[0] != x.cols() {
        return Err(Error::Shape(format!("rmsnorm: x {:?}, g {:?}", x.shape(), g.shape())));
    }
    let (s, h) = (x.rows(), x.cols());
    let mut out = Tensor::zeros(&[s, h]);
    for i in 0..s {
        // one shared row-normalization definition (tensor::rmsnorm_row)
        // keeps this, the fused rmsnorm_matmul, and the serve KV remap
        // bit-identical to each other by construction
        rmsnorm_row(x.row(i), g.data(), out.row_mut(i));
    }
    Ok(out)
}

/// Scaled dot-product attention with causal mask (Eq. 4).
/// `q, k: [s, dk]`, `v: [s, dv]` → `[s, dv]`.
pub fn attention(q: &Tensor, k: &Tensor, v: &Tensor, causal: bool) -> Result<Tensor> {
    let dk = q.cols();
    if k.cols() != dk || q.rows() != k.rows() || k.rows() != v.rows() {
        return Err(Error::Shape(format!(
            "attention: q {:?}, k {:?}, v {:?}",
            q.shape(),
            k.shape(),
            v.shape()
        )));
    }
    let mut scores = q.matmul_bt(k)?;
    let scale = 1.0 / (dk as f32).sqrt();
    scores.scale(scale);
    if causal {
        let s = scores.rows();
        for i in 0..s {
            for j in (i + 1)..s {
                scores.set(i, j, MASK_VALUE);
            }
        }
    }
    // online softmax (one read sweep) + register-tiled probs·V; the
    // incremental KV path (serve::kv::attend) runs the same row pass, so
    // full-tile and decode attention stay bitwise in agreement
    softmax_rows_online(&mut scores);
    scores.attn_pv(v)
}

/// Two-layer ReLU MLP (Eq. 3).
pub fn mlp(x: &Tensor, w1: &Tensor, b1: &Tensor, w2: &Tensor, b2: &Tensor) -> Result<Tensor> {
    let mut hid = x.matmul(w1)?;
    hid.add_row_broadcast(b1)?;
    hid.map_inplace(|v| v.max(0.0));
    let mut out = hid.matmul(w2)?;
    out.add_row_broadcast(b2)?;
    Ok(out)
}

/// Project Q/K/V per head and assemble the `[s, E*v]` concatenation
/// (Eq. 2's MHA body). `head_out` turns one head's `(e, q, k, v)` into its
/// `[s, v]` output: the full path runs [`attention`] over the in-tile keys,
/// the incremental path ([`forward_incremental`]) attends over the KV cache.
fn mha_block(
    cfg: &ModelConfig,
    params: &ParamStore,
    n: usize,
    nrm: &Tensor,
    mut head_out: impl FnMut(usize, Tensor, Tensor, Tensor) -> Result<Tensor>,
) -> Result<Tensor> {
    let s = nrm.rows();
    let mut concat = Tensor::zeros(&[s, cfg.heads * cfg.v]);
    for e in 0..cfg.heads {
        let q = nrm.matmul(params.get(&format!("layer_{n}.head_{e}.wq"))?)?;
        let k = nrm.matmul(params.get(&format!("layer_{n}.head_{e}.wk"))?)?;
        let v = nrm.matmul(params.get(&format!("layer_{n}.head_{e}.wv"))?)?;
        let head = head_out(e, q, k, v)?;
        // concatenate along the feature axis: column block e*v..(e+1)*v
        for i in 0..s {
            let dst = concat.row_mut(i);
            dst[e * cfg.v..(e + 1) * cfg.v].copy_from_slice(head.row(i));
        }
    }
    Ok(concat)
}

/// The MLP half of Eq. 2: `x += MLP(Norm(x))`, shared by both forwards.
/// The Norm→W1 edge runs through the fused [`Tensor::rmsnorm_matmul`]
/// (the `[s,h]` normalized intermediate never materializes); the fusion
/// is bit-identical to the unfused [`rmsnorm`] + matmul pair, so this is
/// a pure speed change. [`mlp`] keeps the unfused reference shape.
fn layer_tail(params: &ParamStore, n: usize, x: &mut Tensor) -> Result<()> {
    let mut hid = x.rmsnorm_matmul(
        params.get(&format!("layer_{n}.g_mlp"))?,
        params.get(&format!("layer_{n}.w1"))?,
    )?;
    hid.add_row_broadcast(params.get(&format!("layer_{n}.b1"))?)?;
    hid.map_inplace(|v| v.max(0.0));
    let mut mlp_out = hid.matmul(params.get(&format!("layer_{n}.w2"))?)?;
    mlp_out.add_row_broadcast(params.get(&format!("layer_{n}.b2"))?)?;
    x.add_assign(&mlp_out)
}

/// One transformer layer (Eq. 2) applied in place to `x: [s, h]`.
fn layer(cfg: &ModelConfig, params: &ParamStore, n: usize, x: &mut Tensor) -> Result<()> {
    // I'_n = I_n + MHA(Norm(I_n))
    let nrm = rmsnorm(x, params.get(&format!("layer_{n}.g_mha"))?)?;
    let concat = mha_block(cfg, params, n, &nrm, |_, q, k, v| attention(&q, &k, &v, true))?;
    let mha_out = concat.matmul(params.get(&format!("layer_{n}.wo"))?)?;
    x.add_assign(&mha_out)?;

    // I_{n+1} = I'_n + MLP(Norm(I'_n))
    layer_tail(params, n, x)
}

/// Embedding + positional lookup for one token, written into `row`.
fn embed_token(embed: &Tensor, pos: &Tensor, token: usize, position: usize, row: &mut [f32]) {
    let erow = embed.row(token);
    let prow = pos.row(position);
    for (j, r) in row.iter_mut().enumerate() {
        *r = erow[j] + prow[j];
    }
}

/// Full forward (Eq. 1) for one sequence: `tokens` (len == seq) → logits
/// `[s, vocab]`.
pub fn forward_one(cfg: &ModelConfig, params: &ParamStore, tokens: &[u32]) -> Result<Tensor> {
    if tokens.len() != cfg.seq {
        return Err(Error::Shape(format!("forward: {} tokens, seq={}", tokens.len(), cfg.seq)));
    }
    let embed = params.get("embed")?;
    let pos = params.get("pos")?;
    let mut x = Tensor::zeros(&[cfg.seq, cfg.hidden]);
    for (i, &t) in tokens.iter().enumerate() {
        if t as usize >= cfg.vocab {
            return Err(Error::Shape(format!("token {t} out of vocab {}", cfg.vocab)));
        }
        embed_token(embed, pos, t as usize, i, x.row_mut(i));
    }
    for n in 0..cfg.layers {
        layer(cfg, params, n, &mut x)?;
    }
    x.matmul(params.get("w_out")?)
}

/// Incremental forward (S15): process **one** token at the cache's next
/// position, appending its K/V (and residual-stream inputs) to `cache`,
/// and return the `[1, vocab]` logits row for that position.
///
/// This is the serving decode path: one position of attention per new
/// token instead of a full-window re-forward. It runs the *same* per-layer
/// code as [`forward_one`] ([`mha_block`] + [`layer_tail`]); only the
/// attention read differs (KV cache vs in-tile keys), with identical
/// floating-point operation order — so with the exact f32 storage
/// (`serve::kv::KvCache`) the returned row is bit-identical to row
/// `cache.len()` of a [`forward_one`] call on the same history
/// (right-padded to `seq`; the causal mask makes the padding invisible).
/// The cross-check test below asserts exactly that. With quantized
/// storage (`serve::kv::QuantKvCache`) the K/V reads are dequantized, so
/// agreement is bounded by the documented drift bound instead
/// (DESIGN.md §17); the residual stream and logits math are unchanged.
pub fn forward_incremental<S: KvStorage>(
    cfg: &ModelConfig,
    params: &ParamStore,
    cache: &mut KvCacheImpl<S>,
    token: u32,
) -> Result<Tensor> {
    if cache.config() != cfg {
        return Err(Error::Shape(format!(
            "forward_incremental: cache laid out for {:?}, params are {:?}",
            cache.config(),
            cfg
        )));
    }
    let position = cache.len();
    if position >= cfg.seq {
        return Err(Error::Shape(format!(
            "forward_incremental: position {position} outside the positional table (seq {})",
            cfg.seq
        )));
    }
    if token as usize >= cfg.vocab {
        return Err(Error::Shape(format!("token {token} out of vocab {}", cfg.vocab)));
    }

    let mut x = Tensor::zeros(&[1, cfg.hidden]);
    embed_token(params.get("embed")?, params.get("pos")?, token as usize, position, x.row_mut(0));

    for n in 0..cfg.layers {
        cache.push_x(n, x.row(0));
        let nrm = rmsnorm(&x, params.get(&format!("layer_{n}.g_mha"))?)?;
        let concat = mha_block(cfg, params, n, &nrm, |e, q, k, v| {
            cache.push_kv(n, e, k.row(0), v.row(0));
            Tensor::from_vec(&[1, cfg.v], cache.attend(n, e, q.row(0)))
        })?;
        let mha_out = concat.matmul(params.get(&format!("layer_{n}.wo"))?)?;
        x.add_assign(&mha_out)?;
        layer_tail(params, n, &mut x)?;
    }
    cache.push_x(cfg.layers, x.row(0));
    cache.bump();
    x.matmul(params.get("w_out")?)
}

/// Batched forward: one `[s, vocab]` logits tensor per batch row.
pub fn forward(cfg: &ModelConfig, params: &ParamStore, batch: &[Vec<u32>]) -> Result<Vec<Tensor>> {
    batch.iter().map(|row| forward_one(cfg, params, row)).collect()
}

/// Mean next-token cross-entropy over the batch (matches
/// `model.py::loss_fn` with externally-shifted targets).
pub fn cross_entropy(logits: &[Tensor], targets: &[Vec<u32>]) -> Result<f32> {
    if logits.len() != targets.len() {
        return Err(Error::Shape("cross_entropy: batch mismatch".into()));
    }
    let mut total = 0.0f64;
    let mut count = 0usize;
    for (l, t) in logits.iter().zip(targets) {
        if l.rows() != t.len() {
            return Err(Error::Shape("cross_entropy: seq mismatch".into()));
        }
        for (i, &tgt) in t.iter().enumerate() {
            let row = l.row(i);
            let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = row.iter().map(|x| (x - max).exp()).sum::<f32>().ln() + max;
            total += f64::from(lse - row[tgt as usize]);
            count += 1;
        }
    }
    Ok((total / count as f64) as f32)
}

/// Max |Δ| between two batched logit sets (preservation metric).
pub fn max_logit_delta(a: &[Tensor], b: &[Tensor]) -> Result<f32> {
    if a.len() != b.len() {
        return Err(Error::Shape("max_logit_delta: batch mismatch".into()));
    }
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        worst = worst.max(x.max_abs_diff(y)?);
    }
    Ok(worst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn cfg() -> ModelConfig {
        ModelConfig { layers: 2, hidden: 16, heads: 2, k: 8, v: 8, mlp: 32, seq: 16, vocab: 32 }
    }

    fn setup(seed: u64) -> (ModelConfig, ParamStore, Vec<Vec<u32>>) {
        let c = cfg();
        let mut rng = Pcg32::seeded(seed);
        let params = ParamStore::init(&c, &mut rng, 0.02);
        let toks = (0..2)
            .map(|_| (0..c.seq).map(|_| rng.below(c.vocab) as u32).collect())
            .collect();
        (c, params, toks)
    }

    #[test]
    fn rmsnorm_known_values() {
        let x = Tensor::from_vec(&[1, 2], vec![3.0, 4.0]).unwrap();
        let g = Tensor::from_vec(&[2], vec![2.0, 0.5]).unwrap();
        let out = rmsnorm(&x, &g).unwrap();
        let rms = ((9.0 + 16.0) / 2.0f32).sqrt();
        assert!((out.at(0, 0) - 2.0 * 3.0 / rms).abs() < 1e-6);
        assert!((out.at(0, 1) - 0.5 * 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_scale_invariance() {
        let mut rng = Pcg32::seeded(0);
        let x = Tensor::randn(&[4, 8], &mut rng, 1.0);
        let g = Tensor::randn(&[8], &mut rng, 1.0);
        let mut x2 = x.clone();
        x2.scale(7.0);
        let a = rmsnorm(&x, &g).unwrap();
        let b = rmsnorm(&x2, &g).unwrap();
        assert!(a.max_abs_diff(&b).unwrap() < 1e-5);
    }

    #[test]
    fn attention_uniform_when_keys_equal() {
        // all-equal keys => causal-uniform weights => running mean of V
        let s = 8;
        let mut rng = Pcg32::seeded(1);
        let q = Tensor::randn(&[s, 4], &mut rng, 1.0);
        let k = Tensor::ones(&[s, 4]);
        let mut v = Tensor::zeros(&[s, 3]);
        for i in 0..s {
            for j in 0..3 {
                v.set(i, j, i as f32);
            }
        }
        let out = attention(&q, &k, &v, true).unwrap();
        for i in 0..s {
            let want = (0..=i).sum::<usize>() as f32 / (i + 1) as f32;
            assert!((out.at(i, 0) - want).abs() < 1e-5, "row {i}");
        }
    }

    #[test]
    fn attention_noncausal_attends_everywhere() {
        let s = 4;
        let q = Tensor::ones(&[s, 2]);
        let k = Tensor::ones(&[s, 2]);
        let mut v = Tensor::zeros(&[s, 1]);
        for i in 0..s {
            v.set(i, 0, i as f32);
        }
        let out = attention(&q, &k, &v, false).unwrap();
        let mean = (0..s).sum::<usize>() as f32 / s as f32;
        for i in 0..s {
            assert!((out.at(i, 0) - mean).abs() < 1e-5);
        }
    }

    #[test]
    fn attention_matches_two_pass_softmax_oracle() {
        // the fused attention path (online softmax + tiled attn_pv) against
        // the retained reference (two-pass softmax_rows + straight-line
        // matmul): agreement is bounded by the online-softmax drift bound,
        // amplified at most by the |V| row magnitudes
        let mut rng = Pcg32::seeded(5);
        let (s, dk, dv) = (8usize, 4usize, 6usize);
        let q = Tensor::randn(&[s, dk], &mut rng, 1.0);
        let k = Tensor::randn(&[s, dk], &mut rng, 1.0);
        let v = Tensor::randn(&[s, dv], &mut rng, 1.0);
        for causal in [true, false] {
            let fused = attention(&q, &k, &v, causal).unwrap();
            let mut scores = q.matmul_bt(&k).unwrap();
            scores.scale(1.0 / (dk as f32).sqrt());
            if causal {
                for i in 0..s {
                    for j in (i + 1)..s {
                        scores.set(i, j, MASK_VALUE);
                    }
                }
            }
            crate::tensor::softmax_rows(&mut scores);
            let oracle = scores.matmul_naive(&v).unwrap();
            assert!(
                fused.max_abs_diff(&oracle).unwrap() <= 1e-5,
                "causal={causal}: fused attention drifted from the two-pass oracle"
            );
        }
    }

    #[test]
    fn attention_shape_errors() {
        let q = Tensor::ones(&[4, 2]);
        assert!(attention(&q, &Tensor::ones(&[4, 3]), &Tensor::ones(&[4, 2]), true).is_err());
        assert!(attention(&q, &Tensor::ones(&[3, 2]), &Tensor::ones(&[4, 2]), true).is_err());
    }

    #[test]
    fn mlp_zero_weights_give_bias() {
        let x = Tensor::ones(&[3, 4]);
        let out = mlp(
            &x,
            &Tensor::zeros(&[4, 8]),
            &Tensor::zeros(&[8]),
            &Tensor::zeros(&[8, 4]),
            &Tensor::full(&[4], 1.5),
        )
        .unwrap();
        assert_eq!(out.data(), &[1.5; 12]);
    }

    #[test]
    fn mlp_relu_blocks_negatives() {
        // single unit with negative pre-activation contributes nothing
        let x = Tensor::ones(&[1, 1]);
        let out = mlp(
            &x,
            &Tensor::from_vec(&[1, 1], vec![-5.0]).unwrap(),
            &Tensor::zeros(&[1]),
            &Tensor::from_vec(&[1, 1], vec![100.0]).unwrap(),
            &Tensor::zeros(&[1]),
        )
        .unwrap();
        assert_eq!(out.data(), &[0.0]);
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let (c, params, toks) = setup(7);
        let out = forward(&c, &params, &toks).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].shape(), &[c.seq, c.vocab]);
        assert!(out.iter().all(Tensor::all_finite));
    }

    #[test]
    fn forward_is_causal() {
        let (c, params, mut toks) = setup(8);
        let base = forward_one(&c, &params, &toks[0]).unwrap();
        let t = c.seq / 2;
        toks[0][t] = (toks[0][t] + 1) % c.vocab as u32;
        let pert = forward_one(&c, &params, &toks[0]).unwrap();
        for i in 0..t {
            for j in 0..c.vocab {
                assert!((base.at(i, j) - pert.at(i, j)).abs() < 1e-6, "leak at ({i},{j})");
            }
        }
        let tail_delta = base.slice_rows(t, c.seq).unwrap().max_abs_diff(&pert.slice_rows(t, c.seq).unwrap()).unwrap();
        assert!(tail_delta > 1e-4, "perturbation had no effect downstream");
    }

    #[test]
    fn forward_rejects_bad_tokens() {
        let (c, params, _) = setup(9);
        let too_short = vec![0u32; c.seq - 1];
        assert!(forward_one(&c, &params, &too_short).is_err());
        let mut bad = vec![0u32; c.seq];
        bad[0] = c.vocab as u32;
        assert!(forward_one(&c, &params, &bad).is_err());
    }

    #[test]
    fn cross_entropy_near_log_vocab_at_init() {
        let (c, params, toks) = setup(10);
        let logits = forward(&c, &params, &toks).unwrap();
        let loss = cross_entropy(&logits, &toks).unwrap();
        assert!((loss - (c.vocab as f32).ln()).abs() < 0.5, "loss {loss}");
    }

    #[test]
    fn cross_entropy_perfect_prediction() {
        // logits with a huge spike at the target => loss ~ 0
        let logits = vec![{
            let mut t = Tensor::zeros(&[2, 4]);
            t.set(0, 1, 50.0);
            t.set(1, 3, 50.0);
            t
        }];
        let loss = cross_entropy(&logits, &[vec![1, 3]]).unwrap();
        assert!(loss < 1e-3);
    }

    #[test]
    fn incremental_forward_is_bitexact_with_full_forward() {
        // feed a prefix token by token; every returned row must equal the
        // matching row of the full forward on the right-padded window.
        let (c, params, toks) = setup(12);
        let mut window = toks[0].clone();
        window.truncate(c.seq);
        let full = forward_one(&c, &params, &{
            let mut w = window.clone();
            w.resize(c.seq, 0);
            w
        })
        .unwrap();
        let mut cache = crate::serve::kv::KvCache::new(&c);
        for (i, &t) in window.iter().enumerate() {
            let row = forward_incremental(&c, &params, &mut cache, t).unwrap();
            assert_eq!(row.shape(), &[1, c.vocab]);
            let want = full.slice_rows(i, i + 1).unwrap();
            assert_eq!(row, want, "position {i} diverged from the full forward");
        }
        assert_eq!(cache.len(), window.len());
    }

    #[test]
    fn incremental_forward_rejects_bad_inputs() {
        let (c, params, _) = setup(13);
        let mut cache = crate::serve::kv::KvCache::new(&c);
        // out-of-vocab token
        assert!(forward_incremental(&c, &params, &mut cache, c.vocab as u32).is_err());
        // config mismatch between cache and params
        let mut other = c;
        other.mlp += 8;
        let mut wrong = crate::serve::kv::KvCache::new(&other);
        assert!(forward_incremental(&c, &params, &mut wrong, 0).is_err());
        // positional-table overflow after seq tokens
        for t in 0..c.seq {
            forward_incremental(&c, &params, &mut cache, (t % c.vocab) as u32).unwrap();
        }
        assert!(forward_incremental(&c, &params, &mut cache, 0).is_err());
    }

    #[test]
    fn max_logit_delta_detects_change() {
        let (c, params, toks) = setup(11);
        let a = forward(&c, &params, &toks).unwrap();
        let mut b = a.clone();
        assert_eq!(max_logit_delta(&a, &b).unwrap(), 0.0);
        b[1].data_mut()[5] += 0.25;
        assert!((max_logit_delta(&a, &b).unwrap() - 0.25).abs() < 1e-6);
    }
}
