//! §5 use case (c): NAS-lite greedy search over expansion schedules.
//!
//! "Neural architecture search techniques could be applied to determine
//! optimal transformation scheduling" — the greedy seed of that idea now
//! lives in the library as the [`GreedyBranch`] growth policy
//! (`texpand train --backend native --policy greedy`); this example drives
//! that machinery directly so the ranking is visible:
//!
//! 1. briefly train the schedule's base architecture;
//! 2. call [`greedy::rank_candidates`] — the policy's core: branch the
//!    checkpoint across every candidate op (+ a keep-training control),
//!    probe-train each for a fixed budget on an identical data stream, and
//!    score by loss improvement per unit of marginal compute. Function
//!    preservation means every branch starts from identical quality, so
//!    the comparison is sound;
//! 3. print the table and the op a greedy schedule search would commit.
//!
//! Runs **fully offline on the native backend by default** (no artifacts).
//! Set `TEXPAND_SEARCH_BACKEND=pjrt` to train the base through the AOT
//! artifact path instead (needs `make artifacts`); candidate probing
//! always runs the native autodiff path — that is what makes the search
//! cheap enough to run inside training.
//!
//! Run: `cargo run --release --example schedule_search [base_steps] [probe_steps]`

use texpand::autodiff::{ExecBackend, NativeBackend};
use texpand::config::{GrowthSchedule, TrainConfig};
use texpand::data::Batcher;
use texpand::growth::greedy;
use texpand::metrics::RunLogger;
use texpand::optim::Optimizer;
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::runtime::{Manifest, Runtime};
use texpand::train::{train_stage, TrainState};

fn main() -> texpand::Result<()> {
    let base_steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    let probe_steps: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(120);
    let backend_kind =
        std::env::var("TEXPAND_SEARCH_BACKEND").unwrap_or_else(|_| "native".to_string());

    assert!(
        backend_kind == "native" || backend_kind == "pjrt",
        "TEXPAND_SEARCH_BACKEND must be native|pjrt, got '{backend_kind}'"
    );
    let schedule = GrowthSchedule::load("configs/growth_default.json")?;
    let manifest = match backend_kind.as_str() {
        "native" => Manifest::from_schedule(&schedule),
        _ => Manifest::load("artifacts", "manifest.json")?,
    };
    let mut backend: Box<dyn ExecBackend> = if backend_kind == "native" {
        Box::new(NativeBackend::new())
    } else {
        Box::new(Runtime::cpu()?)
    };
    let tcfg = TrainConfig { log_every: 1000, ..Default::default() };

    // 1. briefly train the base architecture
    let exec0 = backend.load_stage(&manifest, "stage0")?;
    let cfg0 = exec0.meta.config;
    let mut rng = Pcg32::seeded(tcfg.seed);
    let mut base = ParamStore::init(&cfg0, &mut rng, 0.02);
    let mut opt = Optimizer::new(&tcfg, &base);
    let mut batcher = Batcher::from_corpus(
        texpand::data::CorpusKind::MarkovText,
        200_000,
        cfg0.vocab,
        cfg0.seq,
        schedule.batch,
        tcfg.seed ^ 0xC0DE,
    )?;
    let mut logger = RunLogger::create("runs", "search-base")?.quiet();
    let mut state = TrainState::new();
    train_stage(
        backend.as_ref(),
        &exec0,
        &mut base,
        &mut opt,
        &mut batcher,
        &tcfg,
        &mut logger,
        &mut state,
        base_steps,
    )?;

    // 2. the GreedyBranch policy's core: branch + probe + score
    let ranked = greedy::rank_candidates(&base, &opt, &batcher, &tcfg, probe_steps, tcfg.seed)?;
    let base_eval = ranked[0].eval_at_branch;
    println!(
        "base ({} params, {backend_kind} backend) eval loss after {base_steps} steps: {base_eval:.4}",
        base.num_scalars()
    );

    println!(
        "\n{:<24} {:>12} {:>10} {:>10} {:>10} {:>14}",
        "candidate", "params", "branch", "eval", "Δloss", "Δloss/Tflop~"
    );
    let mut best: Option<&greedy::CandidateScore> = None;
    for c in &ranked {
        let label = if c.plan.is_identity() {
            "control (no expand)".to_string()
        } else {
            format!("{:?}", c.plan.ops()[0])
        };
        println!(
            "{:<24} {:>12} {:>10.4} {:>10.4} {:>10.4} {:>14.3}",
            label, c.params, c.eval_at_branch, c.eval_after, c.dloss, c.score
        );
        if c.score.is_finite() && best.map(|b| c.score > b.score).unwrap_or(true) {
            best = Some(c);
        }
    }

    // 3. the greedy commitment
    let winner = best.expect("at least the control candidate scores");
    if winner.plan.is_identity() {
        println!(
            "\ngreedy schedule search: keep training — no expansion pays for its compute yet \
             (control Δloss per compute = {:.3}).",
            winner.score
        );
    } else {
        println!(
            "\ngreedy schedule search: expand with {:?} next (Δloss per compute = {:.3}; \
             plan: {}).",
            winner.plan.ops()[0],
            winner.score,
            winner.plan.summary()
        );
    }
    println!(
        "Every candidate branched from the *same* function (branch column ≈ base eval — \n\
         preservation ⇒ fair comparison). The same machinery runs inside training via\n\
         `texpand train --backend native --policy greedy`."
    );
    Ok(())
}
