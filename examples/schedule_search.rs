//! §5 use case (c): NAS-lite greedy search over expansion schedules.
//!
//! "Neural architecture search techniques could be applied to determine
//! optimal transformation scheduling" — this example implements the greedy
//! seed of that idea. Starting from a briefly-trained base model, it
//! evaluates every candidate *next expansion* (the architecture stages the
//! AOT manifest provides) by branching the checkpoint — function-preserving,
//! so every candidate starts from identical quality — finetuning each for a
//! fixed probe budget, and ranking candidates by loss improvement per unit
//! of marginal compute. The best candidate is the schedule step a greedy
//! NAS would commit to before repeating.
//!
//! Requires artifacts: `make artifacts`.
//! Run: `cargo run --release --example schedule_search [base_steps] [probe_steps]`

use texpand::config::{GrowthSchedule, TrainConfig};
use texpand::coordinator::{Coordinator, CoordinatorOptions};
use texpand::data::Batcher;
use texpand::metrics::RunLogger;
use texpand::optim::Optimizer;
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::runtime::{Manifest, Runtime};
use texpand::train::{eval_loss, train_stage, TrainState};

fn main() -> texpand::Result<()> {
    let base_steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    let probe_steps: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(120);

    let schedule = GrowthSchedule::load("configs/growth_default.json")?;
    let manifest = Manifest::load("artifacts", "manifest.json")?;
    let tcfg = TrainConfig { log_every: 1000, ..Default::default() };
    let mut coord = Coordinator::new(
        schedule.clone(),
        manifest.clone(),
        Box::new(Runtime::cpu()?),
        tcfg.clone(),
        CoordinatorOptions::default(),
    )?;

    // 1. briefly train the base architecture
    let mut rt = Runtime::cpu()?;
    let exec0 = rt.load_stage(&manifest, "stage0")?;
    let cfg0 = exec0.meta.config;
    let mut rng = Pcg32::seeded(tcfg.seed);
    let mut base = ParamStore::init(&cfg0, &mut rng, 0.02);
    let mut opt = Optimizer::new(&tcfg, &base);
    let mut batcher = Batcher::from_corpus(
        coord.opts.corpus,
        coord.opts.corpus_len,
        cfg0.vocab,
        cfg0.seq,
        schedule.batch,
        tcfg.seed ^ 0xC0DE,
    )?;
    let mut logger = RunLogger::create("runs", "search-base")?.quiet();
    let mut state = TrainState::new();
    train_stage(&rt, &exec0, &mut base, &mut opt, &mut batcher, &tcfg, &mut logger, &mut state, base_steps)?;
    let probe = batcher.probe(tcfg.seed ^ 0xE7A1);
    let base_eval = eval_loss(&rt, &exec0, &base, &probe)?;
    println!("base ({} params) eval loss after {base_steps} steps: {base_eval:.4}", base.num_scalars());

    // 2. candidate next-expansions = every larger manifest stage; greedy
    //    scoring = Δloss per probe budget, penalized by marginal step cost.
    println!(
        "\n{:<10} {:>12} {:>10} {:>10} {:>12} {:>14}",
        "candidate", "params", "eval", "Δloss", "probe tok/s", "Δloss/Gflop~"
    );
    let mut best: Option<(String, f64)> = None;
    // candidate 0 is the control: keep training the base without expanding
    for i in 0..schedule.stages.len() {
        let stage = schedule.stages[i].clone();
        let ops: Vec<_> = if i == 0 { vec![] } else { schedule.stages[1..=i].iter().flat_map(|s| s.apply.clone()).collect() };
        let (branched, report, eval) = coord.branch(
            &base,
            &ops,
            &stage.name,
            probe_steps,
            "runs",
            &format!("search-{}", stage.name),
            &probe,
        )?;
        let dloss = f64::from(base_eval - eval);
        // compute proxy for the probe: steps * params * tokens (relative)
        let compute = probe_steps as f64 * branched.num_scalars() as f64
            * (schedule.batch * stage.config.seq) as f64
            / 1e12;
        let score = dloss / compute;
        println!(
            "{:<10} {:>12} {:>10.4} {:>10.4} {:>12.0} {:>14.3}",
            stage.name,
            branched.num_scalars(),
            eval,
            dloss,
            report.tokens_per_sec,
            score
        );
        if best.as_ref().map(|(_, s)| score > *s).unwrap_or(true) {
            best = Some((stage.name.clone(), score));
        }
    }
    let (winner, score) = best.expect("at least one candidate");
    println!(
        "\ngreedy schedule search: expand to `{winner}` next (Δloss per compute = {score:.3}).\n\
         Every candidate started from the *same* function (preservation ⇒ fair comparison) —\n\
         the property that makes cheap greedy architecture search sound for growth schedules."
    );
    Ok(())
}
