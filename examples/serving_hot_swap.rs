//! Serving + hot-swap end to end: train-ish → serve → expand-under-load →
//! verify-identical-outputs, all on the pure-Rust reference path (no AOT
//! artifacts needed).
//!
//! The demo stands up the KV-cached batched engine on a small model, puts
//! generations in flight, grows the live model with a composed
//! function-preserving expansion (Defs. 3.1/3.2/3.6) **between scheduler
//! ticks**, and then proves the paper's serving-side payoff: every greedy
//! completion is byte-identical to a rollout that never saw the swap, and
//! a constraint-violating swap (the E6 ablation) is rejected by the
//! preservation probe without disturbing traffic.
//!
//! Run: `cargo run --release --example serving_hot_swap`

use texpand::config::{GrowthOp, LayerPosition, ModelConfig};
use texpand::expand::{ExpandOptions, ExpansionPlan, Init};
use texpand::generate::{generate_ref, Sampler};
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::serve::{Engine, EngineOptions};

fn main() -> texpand::Result<()> {
    // a small serving model; in production this would be a trained
    // checkpoint (`texpand serve --ckpt ...`)
    let cfg = ModelConfig { layers: 2, hidden: 32, heads: 2, k: 16, v: 16, mlp: 64, seq: 32, vocab: 64 };
    let mut rng = Pcg32::seeded(42);
    let params = ParamStore::init(&cfg, &mut rng, 0.05);
    println!("live model: {:?} ({} params)", cfg, params.num_scalars());

    // four requests, greedy so outputs are comparable token by token
    let greedy = Sampler { temperature: 0.0, top_k: None, seed: 0 };
    let prompts: Vec<Vec<u32>> =
        (0..4).map(|i| (0..3).map(|_| ((7 * i + 11) % cfg.vocab) as u32).collect()).collect();
    let new_tokens = 24;

    // oracle: the full KV-less rollout under the *original* model
    let reference = generate_ref(&params, &prompts, new_tokens, &greedy)?;

    // serve with generations in flight...
    let mut engine =
        Engine::new(params, EngineOptions { max_slots: 4, ..Default::default() });
    let ids: Vec<_> = prompts
        .iter()
        .map(|p| engine.submit(p.clone(), new_tokens, greedy))
        .collect::<texpand::Result<_>>()?;
    for _ in 0..8 {
        engine.tick()?;
    }
    println!("{} sequences in flight after 8 ticks", engine.pending());

    // ...grow the live model mid-flight (Defs. 3.1 + 3.2 + 3.6 composed
    // into one validated, inspectable ExpansionPlan)
    let plan = ExpansionPlan::new(
        engine.config(),
        vec![
            GrowthOp::Mlp { p: 128 },
            GrowthOp::HeadsAdd { count: 1 },
            GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top },
        ],
    )?;
    println!("swap plan: {}", plan.summary());
    let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
    let report = engine.hot_swap(&plan, &mut Pcg32::seeded(9), &opts)?;
    println!(
        "hot-swap committed: {} ops, probe max|Δ logits| = {:.3e}, params {} -> {} \
         (predicted {}), {} in-flight KV caches remapped, {:.2} ms",
        report.ops,
        report.probe_delta,
        report.params_before,
        report.params_after,
        report.params_predicted,
        report.remapped_sequences,
        report.swap_ms
    );
    println!("live config is now: {:?}", engine.config());

    // drain and verify: byte-identical continuations across the swap
    engine.run_until_idle()?;
    let mut all_identical = true;
    println!("\n{:<6} {:>8} {:>12}", "req", "tokens", "identical");
    for (id, want) in ids.iter().zip(&reference) {
        let c = engine.poll(*id).expect("completed");
        let ok = &c.tokens == want;
        all_identical &= ok;
        println!("req{:<3} {:>8} {:>12}", id, c.tokens.len(), ok);
    }
    assert!(all_identical, "a continuation diverged across the hot-swap");
    println!("\nall greedy continuations byte-identical across the expansion ✓");

    // negative control: violating the zero-init constraints must be caught
    // by the probe, leaving the (already expanded) engine untouched
    let bad = ExpandOptions { init: Init::Normal(0.5), zero_constrained: false, ..Default::default() };
    let bad_plan = ExpansionPlan::new(engine.config(), vec![GrowthOp::Mlp { p: 256 }])?;
    match engine.hot_swap(&bad_plan, &mut Pcg32::seeded(10), &bad) {
        Err(e) => println!("violating swap rejected as expected: {e}"),
        Ok(_) => panic!("constraint-violating swap must not commit"),
    }

    println!("\ncounters: {}", engine.counters().to_json().to_pretty());
    Ok(())
}
