//! §5 use case (b): branch one checkpoint into a family of model sizes.
//!
//! Trains the smallest stage briefly, saves the checkpoint, then *branches*
//! it to every larger architecture in the schedule (applying the cumulative
//! expansion ops) and finetunes each branch for a fixed budget. Because the
//! expansions are function-preserving, every family member starts from
//! exactly the small model's function — no knowledge is lost at branch
//! time — and larger members improve faster per step.
//!
//! Requires artifacts: `make artifacts`.
//! Run: `cargo run --release --example model_family [train_steps] [finetune_steps]`

use texpand::config::{GrowthSchedule, TrainConfig};
use texpand::coordinator::{Coordinator, CoordinatorOptions};
use texpand::data::Batcher;
use texpand::runtime::{Manifest, Runtime};

fn main() -> texpand::Result<()> {
    let train_steps: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150.0);
    let finetune_steps: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(60);

    let schedule = GrowthSchedule::load("configs/growth_default.json")?;
    let manifest = Manifest::load("artifacts", "manifest.json")?;
    let runtime = Box::new(Runtime::cpu()?);
    let tcfg = TrainConfig { log_every: 50, ..Default::default() };
    let opts = CoordinatorOptions::default();
    let mut coord = Coordinator::new(schedule.clone(), manifest, runtime, tcfg, opts)?;

    // 1. train the base (stage0) model only
    let first_cfg0 = schedule.stages[0].config;
    let mut rt = Runtime::cpu()?;
    let exec0 = rt.load_stage(&coord.manifest, "stage0")?;
    let mut rng = texpand::rng::Pcg32::seeded(coord.tcfg.seed);
    let mut base_params = texpand::params::ParamStore::init(&first_cfg0, &mut rng, 0.02);
    let mut opt = texpand::optim::Optimizer::new(&coord.tcfg, &base_params);
    let mut batcher = Batcher::from_corpus(
        coord.opts.corpus,
        coord.opts.corpus_len,
        first_cfg0.vocab,
        first_cfg0.seq,
        schedule.batch,
        coord.tcfg.seed ^ 0xC0DE,
    )?;
    let mut logger = texpand::metrics::RunLogger::create("runs", "family-base")?.quiet();
    let mut state = texpand::train::TrainState::new();
    let report = texpand::train::train_stage(
        &rt,
        &exec0,
        &mut base_params,
        &mut opt,
        &mut batcher,
        &coord.tcfg,
        &mut logger,
        &mut state,
        train_steps as usize,
    )?;
    let ckpt_path = "runs/family-base/stage0.txpd".to_string();
    base_params.save(&ckpt_path, &texpand::json::Value::obj(vec![("stage", texpand::json::Value::str("stage0"))]))?;
    println!("\nbase model trained: final loss {:.4}, checkpoint {}", report.final_loss, ckpt_path);

    // 2. branch to each larger stage and finetune
    let (base_params, _) = texpand::params::ParamStore::load(&ckpt_path)?;
    let first_cfg = schedule.stages[0].config;
    let probe = Batcher::from_corpus(
        coord.opts.corpus,
        coord.opts.corpus_len,
        first_cfg.vocab,
        first_cfg.seq,
        schedule.batch,
        coord.tcfg.seed ^ 0xC0DE,
    )?
    .probe(coord.tcfg.seed ^ 0xE7A1);

    println!("\n{:<10} {:>12} {:>14} {:>12} {:>12}", "branch", "params", "eval loss", "tok/s", "ops applied");
    for i in 0..schedule.stages.len() {
        let stage = schedule.stages[i].clone();
        let ops: Vec<_> = schedule.stages[1..=i].iter().flat_map(|s| s.apply.clone()).collect();
        let n_ops = ops.len();
        let (branched, report, eval) = coord.branch(
            &base_params,
            &ops,
            &stage.name,
            finetune_steps,
            "runs",
            &format!("family-{}", stage.name),
            &probe,
        )?;
        println!(
            "{:<10} {:>12} {:>14.4} {:>12.0} {:>12}",
            stage.name,
            branched.num_scalars(),
            eval,
            report.tokens_per_sec,
            n_ops
        );
    }
    println!(
        "\nA whole model family from one checkpoint: every member started from the same\n\
         function (zero knowledge lost at branch time) and finetuned for {finetune_steps} steps."
    );
    Ok(())
}
