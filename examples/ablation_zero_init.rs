//! E6 ablation: what happens when the theorems' constraints are violated?
//!
//! For each transformation, expands a *partially trained* model twice —
//! once respecting the zero-init constraints (and scaling factors), once
//! violating them — then measures (a) the function-preservation error and
//! (b) the training loss immediately after the boundary. The violated
//! variants show the loss spike the paper's constraints exist to prevent.
//!
//! Requires artifacts: `make artifacts`.
//! Run: `cargo run --release --example ablation_zero_init`

use texpand::config::{GrowthOp, GrowthSchedule, LayerPosition, TrainConfig};
use texpand::data::{Batcher, CorpusKind};
use texpand::expand::{ExpandOptions, ExpansionPlan, Init};
use texpand::metrics::RunLogger;
use texpand::model::{cross_entropy, forward};
use texpand::optim::Optimizer;
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::runtime::{Manifest, Runtime};
use texpand::train::{train_stage, TrainState};

fn main() -> texpand::Result<()> {
    let schedule = GrowthSchedule::load("configs/growth_default.json")?;
    let manifest = Manifest::load("artifacts", "manifest.json")?;
    let mut runtime = Runtime::cpu()?;
    let tcfg = TrainConfig { log_every: 1000, ..Default::default() };

    // 1. partially train the stage0 model so violations have knowledge to destroy
    let stage0 = runtime.load_stage(&manifest, "stage0")?;
    let cfg0 = stage0.meta.config;
    let mut rng = Pcg32::seeded(7);
    let mut params = ParamStore::init(&cfg0, &mut rng, 0.02);
    let mut opt = Optimizer::new(&tcfg, &params);
    let mut batcher =
        Batcher::from_corpus(CorpusKind::MarkovText, 200_000, cfg0.vocab, cfg0.seq, manifest.batch, 99)?;
    let mut logger = RunLogger::create("runs", "ablation")?.quiet();
    let mut state = TrainState::new();
    let pre = train_stage(&runtime, &stage0, &mut params, &mut opt, &mut batcher, &tcfg, &mut logger, &mut state, 120)?;
    println!("trained base model to loss {:.4}", pre.final_loss);

    let probe = batcher.probe(0xE7A1);
    let base_logits = forward(&cfg0, &params, &probe.tokens)?;
    let base_loss = cross_entropy(&base_logits, &probe.targets)?;

    let cases: Vec<(&str, Vec<GrowthOp>)> = vec![
        ("mlp p128→256", vec![GrowthOp::Mlp { p: 256 }]),
        ("heads_add +1", vec![GrowthOp::HeadsAdd { count: 1 }]),
        ("heads_expand v16→32", vec![GrowthOp::HeadsExpand { v: 32 }]),
        ("attn_expand k16→32", vec![GrowthOp::AttnExpand { k: 32 }]),
        ("hidden h64→96", vec![GrowthOp::Hidden { h: 96 }]),
        ("layers_add +1", vec![GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top }]),
    ];

    println!(
        "\n{:<22} {:>14} {:>12} | {:>14} {:>12}",
        "", "constrained", "", "violated", ""
    );
    println!(
        "{:<22} {:>14} {:>12} | {:>14} {:>12}",
        "transformation", "max|Δ|", "probe loss", "max|Δ|", "probe loss"
    );
    for (name, ops) in &cases {
        let good_opts = ExpandOptions { init: Init::Normal(0.1), ..Default::default() };
        let bad_opts = ExpandOptions {
            init: Init::Normal(0.1),
            zero_constrained: false,
            scale_factors: false,
            scale_power: 1.0,
        };
        let plan = ExpansionPlan::new(params.config(), ops.clone())?;
        let good = plan.materialize(&params, &good_opts, &mut Pcg32::seeded(11))?;
        let bad = plan.materialize(&params, &bad_opts, &mut Pcg32::seeded(11))?;
        let good_logits = forward(good.config(), &good, &probe.tokens)?;
        let bad_logits = forward(bad.config(), &bad, &probe.tokens)?;
        let good_delta = texpand::model::max_logit_delta(&base_logits, &good_logits)?;
        let bad_delta = texpand::model::max_logit_delta(&base_logits, &bad_logits)?;
        let good_loss = cross_entropy(&good_logits, &probe.targets)?;
        let bad_loss = cross_entropy(&bad_logits, &probe.targets)?;
        println!(
            "{:<22} {:>14.3e} {:>12.4} | {:>14.3e} {:>12.4}",
            name, good_delta, good_loss, bad_delta, bad_loss
        );
        assert!(good_delta <= 1e-4, "{name}: constrained expansion must preserve");
        assert!(bad_delta > 1e-2, "{name}: violation should break preservation");
    }
    println!(
        "\nbase probe loss: {base_loss:.4}. Constrained expansions keep it exactly;\n\
         violated ones regress toward (or past) the ln(vocab)={:.3} init loss —\n\
         the training progress the zero-init constraints exist to protect.",
        (cfg0.vocab as f32).ln()
    );
    Ok(())
}
