//! End-to-end driver (DESIGN.md E3): progressive growth training.
//!
//! Trains a byte-level LM through the shipped 4-stage growth schedule on a
//! synthetic Markov corpus via the full three-layer stack (Rust coordinator
//! → PJRT-compiled JAX artifacts), asserting at every expansion boundary
//! that the function — and therefore the loss — is preserved. Writes the
//! loss curve to `runs/progressive/loss.csv` and prints a summary.
//!
//! Requires artifacts: `make artifacts` (or `make build`).
//! Run: `cargo run --release --example progressive_training [steps_scale]`

use texpand::config::{GrowthSchedule, TrainConfig};
use texpand::coordinator::{Coordinator, CoordinatorOptions};
use texpand::data::CorpusKind;
use texpand::runtime::{Manifest, Runtime};

fn main() -> texpand::Result<()> {
    let steps_scale: f64 =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1.0);

    let schedule = GrowthSchedule::load("configs/growth_default.json")?;
    let manifest = Manifest::load("artifacts", "manifest.json")?;
    let runtime = Box::new(Runtime::cpu()?);
    let tcfg = TrainConfig { log_every: 25, ..Default::default() };
    let opts = CoordinatorOptions {
        steps_scale,
        corpus: CorpusKind::MarkovText,
        corpus_len: 200_000,
        ..Default::default()
    };
    let mut coord = Coordinator::new(schedule, manifest, runtime, tcfg, opts)?;
    let summary = coord.run("runs", "progressive")?;

    println!("\n=== progressive training summary ===");
    println!("{:<10} {:>8} {:>10} {:>10} {:>12} {:>10}", "stage", "steps", "first", "final", "tok/s", "ms/step");
    for s in &summary.stages {
        println!(
            "{:<10} {:>8} {:>10.4} {:>10.4} {:>12.0} {:>10.1}",
            s.stage, s.steps_run, s.first_loss, s.final_loss, s.tokens_per_sec, s.step_ms_mean
        );
    }

    println!("\n=== boundary continuity (the paper's claim, measured) ===");
    println!("{:<12} {:>12} {:>12} {:>10} {:>10} {:>10}", "boundary", "rustΔ", "pjrtΔ", "loss_pre", "loss_post", "Δloss");
    for b in &summary.boundaries {
        let dloss = (b.loss_after - b.loss_before).abs();
        println!(
            "{:<12} {:>12.3e} {:>12.3e} {:>10.4} {:>10.4} {:>10.3e}",
            b.into_stage, b.rust_delta, b.pjrt_delta, b.loss_before, b.loss_after, dloss
        );
        assert!(b.rust_delta <= 1e-4, "rust-oracle preservation violated at {}", b.into_stage);
        assert!(b.pjrt_delta <= 1e-4, "pjrt preservation violated at {}", b.into_stage);
        assert!(dloss <= 1e-4, "loss continuity violated at {}", b.into_stage);
    }

    // training must actually have learned something: final eval loss well
    // under the ln(vocab) random-guess baseline
    let baseline = (256f32).ln();
    println!(
        "\nfinal eval loss {:.4} vs ln(vocab) = {:.4} ({} steps, loss curve: {}/loss.csv)",
        summary.final_eval_loss, baseline, summary.total_steps, summary.run_dir
    );
    if steps_scale >= 0.5 {
        assert!(
            summary.final_eval_loss < 0.75 * baseline,
            "model failed to learn: {} vs baseline {}",
            summary.final_eval_loss,
            baseline
        );
    }
    println!("progressive training complete: every boundary function-preserving, loss continuous.");
    Ok(())
}
