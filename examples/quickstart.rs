//! Quickstart: the six function-preserving expansions in ~60 lines.
//!
//! Builds a small random transformer entirely in Rust (no artifacts
//! needed), applies each of the paper's transformations plus the composed
//! all-six sequence, and prints the Table-1-style preservation matrix:
//! `max |logits_before − logits_after|` on a random probe batch.
//!
//! Run: `cargo run --release --example quickstart`

use texpand::config::{GrowthOp, LayerPosition, ModelConfig};
use texpand::expand::{ExpandOptions, ExpansionPlan, Init};
use texpand::model::{forward, max_logit_delta};
use texpand::params::ParamStore;
use texpand::rng::Pcg32;

fn main() -> texpand::Result<()> {
    // a small but non-trivial architecture (paper Section 2 notation)
    let cfg = ModelConfig { layers: 2, hidden: 32, heads: 2, k: 16, v: 16, mlp: 64, seq: 32, vocab: 64 };
    let mut rng = Pcg32::seeded(42);
    let params = ParamStore::init(&cfg, &mut rng, 0.02);
    println!("base model: {:?} ({} params)", cfg, params.num_scalars());

    // a random probe batch
    let tokens: Vec<Vec<u32>> =
        (0..4).map(|_| (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u32).collect()).collect();
    let base_logits = forward(&cfg, &params, &tokens)?;

    // unconstrained new parameters get aggressive random init on purpose:
    // the theorems say preservation holds *regardless* of their values.
    let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };

    let cases: Vec<(&str, Vec<GrowthOp>)> = vec![
        ("3.1 MLP expansion        p 64→128", vec![GrowthOp::Mlp { p: 128 }]),
        ("3.2 Head addition        E 2→4", vec![GrowthOp::HeadsAdd { count: 2 }]),
        ("3.3 Heads expansion      v 16→32", vec![GrowthOp::HeadsExpand { v: 32 }]),
        ("3.4 Attention expansion  k 16→32", vec![GrowthOp::AttnExpand { k: 32 }]),
        ("3.5 Hidden expansion     h 32→48", vec![GrowthOp::Hidden { h: 48 }]),
        ("3.6 Layer addition       N 2→3", vec![GrowthOp::LayersAdd { count: 1, position: LayerPosition::At(1) }]),
        (
            "all six composed",
            vec![
                GrowthOp::Mlp { p: 128 },
                GrowthOp::HeadsAdd { count: 1 },
                GrowthOp::HeadsExpand { v: 24 },
                GrowthOp::AttnExpand { k: 24 },
                GrowthOp::Hidden { h: 48 },
                GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top },
            ],
        ),
    ];

    println!("\n{:<40} {:>12} {:>12} {:>10}", "transformation", "params", "max|Δ|", "preserved");
    for (name, ops) in cases {
        // an ExpansionPlan validates the composition and predicts the
        // outcome before any surgery runs
        let plan = ExpansionPlan::new(&cfg, ops)?;
        let expanded = plan.materialize(&params, &opts, &mut rng)?;
        assert_eq!(expanded.num_scalars(), plan.params_after(), "plan prediction is exact");
        let new_logits = forward(expanded.config(), &expanded, &tokens)?;
        let delta = max_logit_delta(&base_logits, &new_logits)?;
        println!(
            "{:<40} {:>12} {:>12.3e} {:>10}",
            name,
            expanded.num_scalars(),
            delta,
            if delta <= 1e-4 { "yes" } else { "NO" }
        );
        assert!(delta <= 1e-4, "{name} failed preservation");
    }
    println!("\nAll transformations exactly function-preserving (f32 tolerance 1e-4).");
    Ok(())
}
