//! Offline stub of the `xla` PJRT bindings.
//!
//! This container image carries no libxla / PJRT plugin, so the crate
//! presents the same API surface the framework uses and draws a sharp
//! line between the two halves of it:
//!
//! * **Host-side literal marshalling is real.** [`Literal`] stores typed
//!   row-major bytes; `create_from_shape_and_untyped_data` / `to_vec`
//!   validate shapes and round-trip data exactly like the real bindings,
//!   so every unit test of the marshalling layer runs against this stub.
//! * **Device execution is absent.** [`HloModuleProto::from_text_file`]
//!   (the only road into compilation) fails with a clear "PJRT unavailable"
//!   error, so any path that needs real AOT artifacts fails loudly at
//!   artifact-load time rather than silently computing garbage.
//!
//! Swapping this path dependency for the real bindings re-enables the
//! compiled execution path with no source changes in `texpand`.

use std::fmt;

/// Stub error type mirroring the binding's error enum where used.
#[derive(Debug)]
pub enum Error {
    /// Element count does not match the target dimensions.
    WrongElementCount { dims: Vec<usize>, element_count: usize },
    /// The requested operation needs the real PJRT runtime.
    Unavailable(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::WrongElementCount { dims, element_count } => {
                write!(f, "wrong element count {element_count} for dims {dims:?}")
            }
            Error::Unavailable(msg) => write!(f, "PJRT unavailable (stub xla build): {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the framework marshals.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn size_in_bytes(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
        }
    }
}

/// Rust-native scalar types a [`Literal`] can decode to.
pub trait NativeType: Sized {
    const TY: ElementType;
    fn from_le(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(bytes: [u8; 4]) -> Self {
        i32::from_le_bytes(bytes)
    }
}

/// A typed host buffer with a shape — the real part of the stub.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    /// Build a literal from raw little-endian bytes; validates the count.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let expect: usize = dims.iter().product::<usize>() * ty.size_in_bytes();
        if data.len() != expect {
            return Err(Error::WrongElementCount {
                dims: dims.to_vec(),
                element_count: data.len() / ty.size_in_bytes(),
            });
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Decode to a typed vector (type must match the stored element type).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(Error::Unavailable(format!(
                "to_vec type mismatch: literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Destructure a 1-element tuple literal. Stub literals are never
    /// tuples — only reachable after a real execution, which the stub
    /// cannot perform.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::Unavailable("tuple literals require a real execution result".into()))
    }

    /// Destructure a tuple literal (see [`Literal::to_tuple1`]).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("tuple literals require a real execution result".into()))
    }
}

/// Parsed HLO module. The stub cannot parse HLO text: constructing one is
/// the gateway to compilation, so this is where the stub draws its line.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable(format!(
            "cannot parse HLO artifact '{path}' — rebuild with the real xla bindings"
        )))
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle returned by execution (unreachable in the stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("no device buffers in the stub build".into()))
    }
}

/// Compiled executable handle (never constructed in the stub: compilation
/// requires an [`HloModuleProto`], which the stub refuses to produce).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("no execution in the stub build".into()))
    }
}

/// PJRT client. Construction succeeds (the pure-Rust paths — serving,
/// reference forward, surgery — never touch it), execution does not.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (no PJRT)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("compilation requires the real xla bindings".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert_eq!(lit.dims(), &[3]);
    }

    #[test]
    fn literal_validates_count_and_type() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4]).is_err());
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[1, 0, 0, 0]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1]);
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn execution_paths_fail_loudly() {
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
    }
}
