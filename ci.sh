#!/usr/bin/env bash
# One-command gate for PRs: format, lint, build, tier-1 tests.
#
#   ./ci.sh          # everything
#   ./ci.sh --fast   # skip the release build (fmt + clippy + debug tests)
#
# Notes:
# * clippy runs with -D warnings; lints that predate this gate and are
#   stylistic-only are allowlisted below rather than churning the seed
#   code — remove entries as the code is cleaned up.
# * integration tests that need AOT artifacts are #[ignore]d in-tree and
#   stay skipped here; run `cargo test -- --ignored` after `make
#   artifacts` with the real xla bindings.

set -euo pipefail
cd "$(dirname "$0")"

FAST=0
[ "${1:-}" = "--fast" ] && FAST=1

# stylistic lints present in the seed code, allowlisted for -D warnings
CLIPPY_ALLOW=(
  -A clippy::too_many_arguments
  -A clippy::needless_range_loop
)

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings "${CLIPPY_ALLOW[@]}"

if [ "$FAST" = "0" ]; then
  echo "==> cargo build --release (tier-1, step 1)"
  cargo build --release
fi

echo "==> cargo test -q (tier-1, step 2)"
cargo test -q

if [ "$FAST" = "0" ]; then
  echo "==> offline grow-train smoke (native backend, tiny schedule, 2 threads)"
  SMOKE_RUNS="$(mktemp -d)"
  trap 'rm -rf "$SMOKE_RUNS"' EXIT # clean up even when the smoke run fails
  ./target/release/texpand train \
    --backend native \
    --threads 2 \
    --schedule configs/growth_tiny.json \
    --steps-scale 0.2 \
    --runs "$SMOKE_RUNS" --run-name ci-smoke --no-checkpoints \
    --log-every 100

  echo "==> plan dry-run vs trained run (final param count must agree exactly)"
  # `texpand plan` predicts the whole schedule offline as ExpansionPlans;
  # its final param count is a plan *postcondition*, so it must match the
  # params the trained smoke run actually ended on (StageReport.params in
  # the last stage_done event) scalar for scalar.
  PLAN_PARAMS="$(./target/release/texpand plan --schedule configs/growth_tiny.json \
    | grep -E '^final params:' | grep -oE '[0-9]+')"
  TRAIN_PARAMS="$(grep '"event":"stage_done"' "$SMOKE_RUNS/ci-smoke/events.jsonl" \
    | tail -n 1 | grep -oE '"params":[0-9]+' | grep -oE '[0-9]+')"
  if [ -z "$PLAN_PARAMS" ] || [ -z "$TRAIN_PARAMS" ] || [ "$PLAN_PARAMS" != "$TRAIN_PARAMS" ]; then
    echo "ci.sh: plan dry-run final params ($PLAN_PARAMS) != trained final params ($TRAIN_PARAMS)" >&2
    exit 1
  fi

  echo "==> policy-driven grow-train smoke (plateau policy, native backend)"
  ./target/release/texpand train \
    --backend native \
    --threads 2 \
    --schedule configs/growth_tiny.json \
    --policy plateau \
    --runs "$SMOKE_RUNS" --run-name ci-policy-smoke --no-checkpoints \
    --log-every 100
  # every policy run must leave an auditable decision trail (evidence rows
  # in the run log); a silent policy is a broken policy
  if ! grep -q '"event":"decision"' "$SMOKE_RUNS/ci-policy-smoke/events.jsonl"; then
    echo "ci.sh: no decision rows in $SMOKE_RUNS/ci-policy-smoke/events.jsonl" >&2
    exit 1
  fi
  if ! grep -q '"decision":"expand"' "$SMOKE_RUNS/ci-policy-smoke/events.jsonl"; then
    echo "ci.sh: plateau smoke never fired an expansion decision" >&2
    exit 1
  fi

  echo "==> train-step bench smoke (TEXPAND_THREADS=2, tiny budget)"
  # also asserts serial-vs-parallel grads are bit-identical (in-bench check)
  TEXPAND_THREADS=2 TEXPAND_BENCH_BUDGET_MS=60 cargo bench --bench train_step
  # throughput regressions fail fast: the freshest step rows must report a
  # nonzero tokens/sec (a NaN serializes as null and also fails this grep)
  if ! grep '"kind":"step"' runs/bench.jsonl | tail -n 3 | grep -Eq '"tokens_per_sec":[1-9]'; then
    echo "ci.sh: no nonzero tokens/sec step row in runs/bench.jsonl" >&2
    exit 1
  fi
fi

echo "ci.sh: all green"
