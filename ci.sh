#!/usr/bin/env bash
# One-command gate for PRs: format, lint, build, tier-1 tests.
#
#   ./ci.sh          # everything
#   ./ci.sh --fast   # skip the release build (fmt + clippy + debug tests)
#
# Notes:
# * clippy runs with -D warnings; lints that predate this gate and are
#   stylistic-only are allowlisted below rather than churning the seed
#   code — remove entries as the code is cleaned up.
# * integration tests that need AOT artifacts are #[ignore]d in-tree and
#   stay skipped here; run `cargo test -- --ignored` after `make
#   artifacts` with the real xla bindings.

set -euo pipefail
cd "$(dirname "$0")"

FAST=0
[ "${1:-}" = "--fast" ] && FAST=1

# stylistic lints present in the seed code, allowlisted for -D warnings
CLIPPY_ALLOW=(
  -A clippy::too_many_arguments
  -A clippy::needless_range_loop
)

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings "${CLIPPY_ALLOW[@]}"

if [ "$FAST" = "0" ]; then
  echo "==> cargo build --release (tier-1, step 1)"
  cargo build --release
fi

echo "==> cargo test -q (tier-1, step 2)"
cargo test -q

if [ "$FAST" = "0" ]; then
  echo "==> offline grow-train smoke (native backend, tiny schedule, 2 threads)"
  SMOKE_RUNS="$(mktemp -d)"
  trap 'rm -rf "$SMOKE_RUNS"' EXIT # clean up even when the smoke run fails
  ./target/release/texpand train \
    --backend native \
    --threads 2 \
    --schedule configs/growth_tiny.json \
    --steps-scale 0.2 \
    --runs "$SMOKE_RUNS" --run-name ci-smoke --no-checkpoints \
    --log-every 100

  echo "==> plan dry-run vs trained run (final param count must agree exactly)"
  # `texpand plan` predicts the whole schedule offline as ExpansionPlans;
  # its final param count is a plan *postcondition*, so it must match the
  # params the trained smoke run actually ended on (StageReport.params in
  # the last stage_done event) scalar for scalar.
  PLAN_PARAMS="$(./target/release/texpand plan --schedule configs/growth_tiny.json \
    | grep -E '^final params:' | grep -oE '[0-9]+')"
  TRAIN_PARAMS="$(grep '"event":"stage_done"' "$SMOKE_RUNS/ci-smoke/events.jsonl" \
    | tail -n 1 | grep -oE '"params":[0-9]+' | grep -oE '[0-9]+')"
  if [ -z "$PLAN_PARAMS" ] || [ -z "$TRAIN_PARAMS" ] || [ "$PLAN_PARAMS" != "$TRAIN_PARAMS" ]; then
    echo "ci.sh: plan dry-run final params ($PLAN_PARAMS) != trained final params ($TRAIN_PARAMS)" >&2
    exit 1
  fi

  echo "==> run-store + growth-timeline smoke (runs stats / report over the smoke run)"
  # the store must see every expansion of the smoke run with a nonzero
  # measured param delta, and the report must show a preservation
  # measurement at each of the tiny schedule's 2 boundaries
  STATS="$(./target/release/texpand runs stats ci-smoke --runs "$SMOKE_RUNS")"
  if ! echo "$STATS" | grep -Eq '^expansions: [1-9]'; then
    echo "ci.sh: runs stats reported no expansions for ci-smoke" >&2
    echo "$STATS" >&2
    exit 1
  fi
  if ! echo "$STATS" | grep -Eq '^params_delta_total: [1-9]'; then
    echo "ci.sh: runs stats reported a zero param delta for ci-smoke" >&2
    echo "$STATS" >&2
    exit 1
  fi
  REPORT="$(./target/release/texpand report ci-smoke --runs "$SMOKE_RUNS")"
  if [ "$(echo "$REPORT" | grep -c 'preservation: probe')" -lt 2 ]; then
    echo "ci.sh: report missing a preservation row per boundary" >&2
    echo "$REPORT" >&2
    exit 1
  fi

  echo "==> policy-driven grow-train smoke (plateau policy, native backend)"
  ./target/release/texpand train \
    --backend native \
    --threads 2 \
    --schedule configs/growth_tiny.json \
    --policy plateau \
    --runs "$SMOKE_RUNS" --run-name ci-policy-smoke --no-checkpoints \
    --log-every 100
  # every policy run must leave an auditable decision trail (evidence rows
  # in the run log); a silent policy is a broken policy
  if ! grep -q '"event":"decision"' "$SMOKE_RUNS/ci-policy-smoke/events.jsonl"; then
    echo "ci.sh: no decision rows in $SMOKE_RUNS/ci-policy-smoke/events.jsonl" >&2
    exit 1
  fi
  if ! grep -q '"decision":"expand"' "$SMOKE_RUNS/ci-policy-smoke/events.jsonl"; then
    echo "ci.sh: plateau smoke never fired an expansion decision" >&2
    exit 1
  fi

  echo "==> crash/resume smoke (kill at step 5, resume must be bit-identical)"
  # oracle: the same run never interrupted; the resumed run's final params
  # must match it byte for byte (DESIGN.md §16)
  ./target/release/texpand train \
    --backend native --threads 2 \
    --schedule configs/growth_tiny.json --steps-scale 0.2 \
    --runs "$SMOKE_RUNS" --run-name ci-resume-oracle --log-every 100
  if TEXPAND_FAULT=train_step:5 ./target/release/texpand train \
    --backend native --threads 2 \
    --schedule configs/growth_tiny.json --steps-scale 0.2 \
    --runs "$SMOKE_RUNS" --run-name ci-resume \
    --checkpoint-every 1 --log-every 100 > /dev/null 2>&1; then
    echo "ci.sh: fault-armed run was supposed to abort at step 5" >&2
    exit 1
  fi
  ./target/release/texpand train \
    --backend native --threads 2 \
    --schedule configs/growth_tiny.json --steps-scale 0.2 \
    --runs "$SMOKE_RUNS" --run-name ci-resume \
    --checkpoint-every 1 --resume --log-every 100
  if ! cmp -s "$SMOKE_RUNS/ci-resume/stage2.txpd" "$SMOKE_RUNS/ci-resume-oracle/stage2.txpd"; then
    echo "ci.sh: resumed final params differ from the uninterrupted oracle" >&2
    exit 1
  fi
  # the recovery trail must be in the event log: checkpoint rows from
  # before the kill, a resume row from the restart
  if ! grep -q '"event":"checkpoint"' "$SMOKE_RUNS/ci-resume/events.jsonl"; then
    echo "ci.sh: no checkpoint rows in $SMOKE_RUNS/ci-resume/events.jsonl" >&2
    exit 1
  fi
  if ! grep -q '"event":"resume"' "$SMOKE_RUNS/ci-resume/events.jsonl"; then
    echo "ci.sh: no resume row in $SMOKE_RUNS/ci-resume/events.jsonl" >&2
    exit 1
  fi

  echo "==> checkpoint chain verify smoke (texpand ckpt on the crash/resume chain)"
  # the resumed run above left a real generation chain behind; `ckpt
  # verify` must validate it without resuming, and `ckpt list` must show
  # at least one valid generation row
  ./target/release/texpand ckpt verify "$SMOKE_RUNS/ci-resume/ckpt"
  if ! ./target/release/texpand ckpt list "$SMOKE_RUNS/ci-resume/ckpt" | grep -q 'valid'; then
    echo "ci.sh: ckpt list shows no valid generation for ci-resume" >&2
    exit 1
  fi
  # a corrupt-only chain must exit nonzero (the resumability gate)
  BAD_CHAIN="$SMOKE_RUNS/bad-chain"
  mkdir -p "$BAD_CHAIN"
  printf 'TXCKgarbage' > "$BAD_CHAIN/gen-000001.txck"
  if ./target/release/texpand ckpt verify "$BAD_CHAIN" > /dev/null 2>&1; then
    echo "ci.sh: ckpt verify passed a corrupt-only chain" >&2
    exit 1
  fi

  echo "==> train-step bench smoke (TEXPAND_THREADS=2, tiny budget)"
  # also asserts serial-vs-parallel grads are bit-identical, and that the
  # batch-1 within-row per-head backward is bit-identical at 1/2/4 threads
  # (both in-bench checks)
  TEXPAND_THREADS=2 TEXPAND_BENCH_BUDGET_MS=60 cargo bench --bench train_step
  # throughput regressions fail fast: the freshest step rows must report a
  # nonzero tokens/sec (a NaN serializes as null and also fails this grep)
  if ! grep '"kind":"step"' runs/bench.jsonl | tail -n 3 | grep -Eq '"tokens_per_sec":[1-9]'; then
    echo "ci.sh: no nonzero tokens/sec step row in runs/bench.jsonl" >&2
    exit 1
  fi
  # the ISSUE 9 within-row series must land with nonzero throughput
  if ! grep '"kind":"backward_within_row_threads"' runs/bench.jsonl | tail -n 4 \
    | grep -Eq '"tokens_per_sec":[1-9]'; then
    echo "ci.sh: no nonzero backward_within_row_threads row in runs/bench.jsonl" >&2
    exit 1
  fi

  echo "==> fused-kernels bench smoke (oracle equivalence + quant KV ratio)"
  # in-bench asserts: fused kernels bit-identical to their naive oracles,
  # online softmax within its bound, quant KV >= 3x fewer resident bytes
  TEXPAND_BENCH_BUDGET_MS=60 cargo bench --bench fused_kernels
  if ! grep '"kind":"fused_kernels"' runs/bench.jsonl | tail -n 8 | grep -Eq '"speedup":[0-9]*\.?[0-9]*[1-9]'; then
    echo "ci.sh: no nonzero fused_kernels speedup row in runs/bench.jsonl" >&2
    exit 1
  fi
  if ! grep '"kind":"kv_quant"' runs/bench.jsonl | tail -n 3 | grep -Eq '"bytes_ratio":[3-9]'; then
    echo "ci.sh: no kv_quant row with bytes_ratio >= 3 in runs/bench.jsonl" >&2
    exit 1
  fi

  echo "==> serve metrics smoke (live /metrics scrape over HTTP + span log)"
  # the binary is its own scraper (`texpand scrape`): CI images have no
  # curl. Port 0 picks a free port; the resolved address is parsed from
  # the linger line, which only prints after serving drained — so the
  # scrape below must see nonzero counters.
  SERVE_LOG="$SMOKE_RUNS/serve-smoke.log"
  ./target/release/texpand serve \
    --requests 6 --tokens 32 --slots 2 --serial \
    --metrics-addr 127.0.0.1:0 --metrics-linger-ms 30000 \
    --runs "$SMOKE_RUNS" --run-name ci-serve-smoke > "$SERVE_LOG" 2>&1 &
  SERVE_PID=$!
  ADDR=""
  for _ in $(seq 1 300); do
    ADDR="$(sed -n 's|^metrics lingering on http://\([^ ]*\) .*|\1|p' "$SERVE_LOG")"
    [ -n "$ADDR" ] && break
    sleep 0.1
  done
  if [ -z "$ADDR" ]; then
    echo "ci.sh: serve never reached the metrics linger phase" >&2
    cat "$SERVE_LOG" >&2
    exit 1
  fi
  SCRAPE="$(./target/release/texpand scrape --addr "$ADDR")"
  if ! echo "$SCRAPE" | grep -Eq '^texpand_serve_tokens_generated_total [1-9]'; then
    echo "ci.sh: scrape missing nonzero texpand_serve_tokens_generated_total" >&2
    echo "$SCRAPE" >&2
    exit 1
  fi
  if ! echo "$SCRAPE" | grep -q '^# TYPE texpand_serve_decode_latency_ms histogram'; then
    echo "ci.sh: scrape missing decode latency histogram TYPE header" >&2
    exit 1
  fi
  if ! echo "$SCRAPE" | grep -q 'texpand_serve_decode_latency_ms_bucket{le="+Inf"}'; then
    echo "ci.sh: decode latency histogram has no +Inf bucket" >&2
    exit 1
  fi
  ./target/release/texpand scrape --addr "$ADDR" --path /quitz > /dev/null
  wait "$SERVE_PID"
  if ! grep -q '"event":"span"' "$SMOKE_RUNS/ci-serve-smoke/events.jsonl"; then
    echo "ci.sh: no span rows in $SMOKE_RUNS/ci-serve-smoke/events.jsonl" >&2
    exit 1
  fi

  echo "==> http serve smoke (chunked streaming + loadgen fleet)"
  # the binary is its own load generator (`texpand loadgen`): a small
  # closed-loop fleet must stream every request clean over real sockets
  # and append a serve_http_load row to runs/bench.jsonl
  HTTP_LOG="$SMOKE_RUNS/http-smoke.log"
  ./target/release/texpand serve \
    --http-addr 127.0.0.1:0 --http-max-secs 120 --slots 4 --serial \
    --runs "$SMOKE_RUNS" --run-name ci-http-smoke > "$HTTP_LOG" 2>&1 &
  HTTP_PID=$!
  HADDR=""
  for _ in $(seq 1 300); do
    HADDR="$(sed -n 's|^serving on http://\([^ ]*\).*|\1|p' "$HTTP_LOG")"
    [ -n "$HADDR" ] && break
    sleep 0.1
  done
  if [ -z "$HADDR" ]; then
    echo "ci.sh: http serve never printed its address" >&2
    cat "$HTTP_LOG" >&2
    exit 1
  fi
  LOADGEN_OUT="$(./target/release/texpand loadgen --addr "$HADDR" \
    --clients 2 --requests 6 --tokens 8 --prompt-mix 4,8 --case ci-http-smoke)"
  if ! echo "$LOADGEN_OUT" | grep -q '6 sent -> 6 completed, 0 rejected (429), 0 timeouts, 0 errors'; then
    echo "ci.sh: loadgen fleet did not stream clean" >&2
    echo "$LOADGEN_OUT" >&2
    cat "$HTTP_LOG" >&2
    exit 1
  fi
  if ! grep '"kind":"serve_http_load"' runs/bench.jsonl | tail -n 1 | grep -Eq '"tokens_per_sec":[1-9]'; then
    echo "ci.sh: no nonzero serve_http_load row in runs/bench.jsonl" >&2
    exit 1
  fi
  ./target/release/texpand scrape --addr "$HADDR" --path /quitz > /dev/null
  wait "$HTTP_PID"
  if ! grep -Eq 'http summary: [0-9]+ requests, [1-9][0-9]* streamed' "$HTTP_LOG"; then
    echo "ci.sh: http serve summary missing streamed requests" >&2
    cat "$HTTP_LOG" >&2
    exit 1
  fi

  echo "==> http admission smoke (window pinned to 1 must shed with 429)"
  # 4 closed-loop clients against a static window of 1: overlapping
  # arrivals are shed, never queued — the overload defense in one line
  ./target/release/texpand serve \
    --http-addr 127.0.0.1:0 --http-max-secs 120 --slots 4 --serial \
    --admission static --window-init 1 --window-min 1 --window-max 1 \
    --runs "$SMOKE_RUNS" --run-name ci-http-shed > "$HTTP_LOG" 2>&1 &
  HTTP_PID=$!
  HADDR=""
  for _ in $(seq 1 300); do
    HADDR="$(sed -n 's|^serving on http://\([^ ]*\).*|\1|p' "$HTTP_LOG")"
    [ -n "$HADDR" ] && break
    sleep 0.1
  done
  if [ -z "$HADDR" ]; then
    echo "ci.sh: http shed serve never printed its address" >&2
    cat "$HTTP_LOG" >&2
    exit 1
  fi
  LOADGEN_OUT="$(./target/release/texpand loadgen --addr "$HADDR" \
    --clients 4 --requests 8 --tokens 32 --case ci-http-shed)"
  if ! echo "$LOADGEN_OUT" | grep -Eq ' [1-9][0-9]* rejected \(429\)'; then
    echo "ci.sh: pinned window 1 shed nothing under 4 concurrent clients" >&2
    echo "$LOADGEN_OUT" >&2
    exit 1
  fi
  ./target/release/texpand scrape --addr "$HADDR" --path /quitz > /dev/null
  wait "$HTTP_PID"

  echo "==> run-store retention smoke (runs compact keeps summaries)"
  # compact everything but the 2 newest runs: record payloads go, the
  # per-run summaries stay, and stats on a compacted run says so
  COMPACT_OUT="$(./target/release/texpand runs compact --runs "$SMOKE_RUNS" --keep 2)"
  if ! echo "$COMPACT_OUT" | grep -Eq '^compacted [1-9]'; then
    echo "ci.sh: runs compact retired nothing" >&2
    echo "$COMPACT_OUT" >&2
    exit 1
  fi
  if [ ! -f "$SMOKE_RUNS/.store/ci-smoke/summary.json" ]; then
    echo "ci.sh: compaction dropped ci-smoke's summary.json" >&2
    exit 1
  fi
  if [ -f "$SMOKE_RUNS/.store/ci-smoke/records.jsonl" ]; then
    echo "ci.sh: compaction kept ci-smoke's records.jsonl (oldest run)" >&2
    exit 1
  fi
  if ./target/release/texpand runs stats ci-smoke --runs "$SMOKE_RUNS" > /dev/null 2>&1; then
    echo "ci.sh: stats on a compacted run should explain itself and fail" >&2
    exit 1
  fi

  echo "==> serve-http-load bench smoke (adaptive vs static at 8x overload)"
  # in-bench asserts: the AIMD server sheds under 8x overload and bounds
  # client p99 at or below the static wide-window baseline's
  TEXPAND_BENCH_BUDGET_MS=60 cargo bench --bench serve_http_load
  if ! grep '"case":"adaptive-8x-overload"' runs/bench.jsonl | tail -n 1 | grep -Eq '"rejected":[1-9]'; then
    echo "ci.sh: adaptive-8x-overload row missing or shed nothing" >&2
    exit 1
  fi
  if ! grep '"case":"static-8x-overload"' runs/bench.jsonl | tail -n 1 | grep -Eq '"rejected":0'; then
    echo "ci.sh: static-8x-overload row missing or unexpectedly shed" >&2
    exit 1
  fi

  echo "==> runtime-overhead bench smoke (metrics + span-export decode cost)"
  # artifact-free sections only (the PJRT decomposition self-skips); the
  # freshest rows must include both overhead fractions
  TEXPAND_THREADS=2 TEXPAND_BENCH_BUDGET_MS=60 cargo bench --bench runtime_overhead
  if ! grep '"kind":"metrics_overhead"' runs/bench.jsonl | tail -n 3 | grep -q '"overhead_fraction":'; then
    echo "ci.sh: no metrics_overhead overhead_fraction row in runs/bench.jsonl" >&2
    exit 1
  fi
  if ! grep '"kind":"span_export_overhead"' runs/bench.jsonl | tail -n 3 | grep -q '"overhead_fraction":'; then
    echo "ci.sh: no span_export_overhead overhead_fraction row in runs/bench.jsonl" >&2
    exit 1
  fi
  if ! grep '"kind":"checkpoint_write_overhead"' runs/bench.jsonl | tail -n 3 | grep -q '"overhead_fraction":'; then
    echo "ci.sh: no checkpoint_write_overhead overhead_fraction row in runs/bench.jsonl" >&2
    exit 1
  fi
fi

echo "ci.sh: all green"
