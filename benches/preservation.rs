//! E1/E2 — the executable form of the paper's Table 1.
//!
//! For each of the six transformations (Thms 3.1–3.6), for the composed
//! all-six sequence, and for every growth-schedule boundary, report
//! `max |logits_before − logits_after|` through two independent harnesses:
//!
//!   * rust-oracle — the pure-Rust reference forward (`texpand::model`);
//!   * pjrt — the AOT-compiled JAX graphs of the two adjacent stages.
//!
//! Paper claim: exactly zero (in ℝ). Expected here: ≤ ~1e-5 (f32 rounding
//! from the two scaling factors), vs ≥ 1e-2 for the violated controls.
//!
//! Run: `cargo bench --bench preservation`

use texpand::bench_util::Reporter;
use texpand::config::{GrowthOp, LayerPosition, ModelConfig};
use texpand::expand::{ExpandOptions, ExpansionPlan, Init};
use texpand::json::Value;
use texpand::model::{forward, max_logit_delta};
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::runtime::{Manifest, Runtime};

fn main() {
    let mut rep = Reporter::new("preservation (Table 1)");

    // ---- rust-oracle matrix -------------------------------------------------
    let cfg = ModelConfig { layers: 2, hidden: 32, heads: 2, k: 16, v: 16, mlp: 64, seq: 32, vocab: 64 };
    // 0.15 init: large enough that attention scores are O(1) and violated
    // controls separate cleanly, small enough that preservation stays ~1e-6
    let mut rng = Pcg32::seeded(1);
    let params = ParamStore::init(&cfg, &mut rng, 0.15);
    let tokens: Vec<Vec<u32>> =
        (0..4).map(|_| (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u32).collect()).collect();
    let base = forward(&cfg, &params, &tokens).expect("base forward");

    let cases: Vec<(&str, Vec<GrowthOp>)> = vec![
        ("3.1 mlp p64->128", vec![GrowthOp::Mlp { p: 128 }]),
        ("3.2 heads_add E2->4", vec![GrowthOp::HeadsAdd { count: 2 }]),
        ("3.3 heads_expand v16->32", vec![GrowthOp::HeadsExpand { v: 32 }]),
        ("3.4 attn_expand k16->32", vec![GrowthOp::AttnExpand { k: 32 }]),
        ("3.5 hidden h32->48", vec![GrowthOp::Hidden { h: 48 }]),
        ("3.6 layers_add N2->3", vec![GrowthOp::LayersAdd { count: 1, position: LayerPosition::At(1) }]),
        (
            "composed all-six",
            vec![
                GrowthOp::Mlp { p: 128 },
                GrowthOp::HeadsAdd { count: 1 },
                GrowthOp::HeadsExpand { v: 24 },
                GrowthOp::AttnExpand { k: 24 },
                GrowthOp::Hidden { h: 48 },
                GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top },
            ],
        ),
    ];
    let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
    let violated = ExpandOptions {
        init: Init::Normal(0.3),
        zero_constrained: false,
        scale_factors: false,
        scale_power: 1.0,
    };
    for (name, ops) in &cases {
        let plan = ExpansionPlan::new(&cfg, ops.clone()).expect(name);
        let good = plan.materialize(&params, &opts, &mut Pcg32::seeded(2)).expect(name);
        assert_eq!(good.num_scalars(), plan.params_after(), "{name}: plan param prediction");
        let d = max_logit_delta(&base, &forward(good.config(), &good, &tokens).unwrap()).unwrap();
        rep.value_row(&format!("rust-oracle  {name}"), "max_abs_delta", d as f64, vec![
            ("harness", Value::str("rust")),
            ("violated", Value::Bool(false)),
        ]);
        let bad = plan.materialize(&params, &violated, &mut Pcg32::seeded(2)).expect(name);
        let d = max_logit_delta(&base, &forward(bad.config(), &bad, &tokens).unwrap()).unwrap();
        rep.value_row(&format!("rust-oracle  {name} [VIOLATED]"), "max_abs_delta", d as f64, vec![
            ("harness", Value::str("rust")),
            ("violated", Value::Bool(true)),
        ]);
    }

    // ---- pjrt matrix across the shipped schedule ---------------------------
    match (Manifest::load("artifacts", "manifest.json"), Runtime::cpu()) {
        (Ok(manifest), Ok(mut rt)) => {
            let sched_stages = &manifest.stages;
            let cfg0 = sched_stages[0].config;
            let mut rng = Pcg32::seeded(3);
            let mut params = ParamStore::init(&cfg0, &mut rng, 0.02);
            let toks: Vec<Vec<u32>> = (0..manifest.batch)
                .map(|_| (0..cfg0.seq).map(|_| rng.below(cfg0.vocab) as u32).collect())
                .collect();
            let schedule = texpand::config::GrowthSchedule::load("configs/growth_default.json").unwrap();
            let mut prev = rt.load_stage(&manifest, &sched_stages[0].name).unwrap();
            for stage in &schedule.stages[1..] {
                let before = rt.forward(&prev, &params, &toks).unwrap();
                params = ExpansionPlan::new(params.config(), stage.apply.clone())
                    .unwrap()
                    .materialize(&params, &opts, &mut rng)
                    .unwrap();
                let next = rt.load_stage(&manifest, &stage.name).unwrap();
                let after = rt.forward(&next, &params, &toks).unwrap();
                let d = max_logit_delta(&before, &after).unwrap();
                let ops_desc: Vec<&str> = stage.apply.iter().map(|o| o.kind()).collect();
                rep.value_row(
                    &format!("pjrt boundary -> {} ({})", stage.name, ops_desc.join("+")),
                    "max_abs_delta",
                    d as f64,
                    vec![("harness", Value::str("pjrt"))],
                );
                prev = next;
            }
        }
        _ => println!("(artifacts missing — pjrt rows skipped; run `make artifacts`)"),
    }

    rep.flush();
    println!("\npaper: exact preservation (Table 1); measured: <=1e-5 f32, violations >=1e-2.");
}
