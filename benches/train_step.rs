//! Native-backend training-step throughput across model sizes and
//! thread counts.
//!
//! The BENCH trajectory for the offline training path: per-size step
//! latency + tokens/sec through `autodiff::loss_and_grads` +
//! `Optimizer::step`; a thread-scaling series over the data-parallel
//! batch fan-out (rows carry `threads`, `tokens_per_sec` and
//! `speedup_vs_1t`, and the bench *asserts* serial-vs-parallel grads are
//! bit-identical before reporting); a `backward_within_row_threads`
//! series at batch 1, where the per-head decomposition inside
//! `backward_seq_pooled` is the only parallelism available (same
//! bit-identity assertion); and the kernel comparisons that
//! justify the `tensor` hot-path rework — blocked `matmul` vs naive,
//! tiled `matmul_bt` vs naive, blocked `matmul_at` vs naive. Rows append
//! to `runs/bench.jsonl`.
//!
//! Run: `cargo bench --bench train_step` (no artifacts needed).
//! Env: `TEXPAND_BENCH_BUDGET_MS` shrinks the per-case budget for CI
//! smoke runs (default 1500); `TEXPAND_THREADS` sizes the default pool.

use texpand::autodiff::{loss_and_grads, loss_and_grads_pooled};
use texpand::bench_util::{bench_for, Reporter};
use texpand::config::{ModelConfig, OptimKind, TrainConfig};
use texpand::data::Batch;
use texpand::json::Value;
use texpand::optim::Optimizer;
use texpand::parallel::{env_threads, Pool};
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::tensor::Tensor;

fn main() {
    let mut rep = Reporter::new("train_step (native backend)");
    let budget_ms: u64 = std::env::var("TEXPAND_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1500);
    let budget = std::time::Duration::from_millis(budget_ms);

    // three sizes: the test tiny config, the tiny-schedule base, and the
    // default-schedule base
    let cases = [
        ("tiny  (1L h16)", ModelConfig { layers: 1, hidden: 16, heads: 2, k: 8, v: 8, mlp: 32, seq: 16, vocab: 64 }, 4usize),
        ("small (2L h32)", ModelConfig { layers: 2, hidden: 32, heads: 2, k: 16, v: 16, mlp: 64, seq: 32, vocab: 128 }, 4),
        ("base  (2L h64)", ModelConfig { layers: 2, hidden: 64, heads: 2, k: 32, v: 32, mlp: 128, seq: 64, vocab: 256 }, 8),
    ];

    for (label, cfg, batch_rows) in cases {
        let mut rng = Pcg32::seeded(1);
        let mut params = ParamStore::init(&cfg, &mut rng, 0.02);
        let mut opt = Optimizer::new(
            &TrainConfig { optimizer: OptimKind::Adam, ..Default::default() },
            &params,
        );
        let batch = Batch::random(&cfg, batch_rows, 2);
        let tokens_per_step = (batch_rows * cfg.seq) as f64;

        // grads only (the autodiff cost itself, env-sized pool)
        let grad_stats = bench_for(1, budget, || loss_and_grads(&cfg, &params, &batch).unwrap());
        rep.row(
            &format!("{label} loss_and_grads"),
            &grad_stats,
            vec![
                ("kind", Value::str("loss_and_grads")),
                ("params", Value::num(cfg.num_params() as f64)),
                ("threads", Value::num(env_threads() as f64)),
                ("tokens_per_sec", Value::num(grad_stats.per_second(tokens_per_step))),
            ],
        );

        // full step: grads + Adam update
        let step_stats = bench_for(1, budget, || {
            let (loss, grads) = loss_and_grads(&cfg, &params, &batch).unwrap();
            opt.step(&mut params, &grads).unwrap();
            loss
        });
        let tps = step_stats.per_second(tokens_per_step);
        rep.row(
            &format!("{label} step ({tps:.0} tok/s)"),
            &step_stats,
            vec![
                ("kind", Value::str("step")),
                ("params", Value::num(cfg.num_params() as f64)),
                ("threads", Value::num(env_threads() as f64)),
                ("step_ms", Value::num(step_stats.mean_ms())),
                ("tokens_per_sec", Value::num(tps)),
            ],
        );
    }

    // ---- thread scaling on the largest size -------------------------------
    // data-parallel batch fan-out: 1 thread vs the machine; the fixed-order
    // tree reduction makes the grads bit-identical at every count, which is
    // asserted before any timing is reported.
    {
        let (label, cfg, batch_rows) = cases[cases.len() - 1];
        let mut rng = Pcg32::seeded(1);
        let params = ParamStore::init(&cfg, &mut rng, 0.02);
        let batch = Batch::random(&cfg, batch_rows, 2);
        let tokens_per_step = (batch_rows * cfg.seq) as f64;

        let mut counts = vec![1usize, 2, env_threads()];
        counts.sort_unstable();
        counts.dedup();

        // compare bit patterns, not f32 == (which treats -0.0 == +0.0):
        // the claim is bit-identity, so the check must be that strong
        let bits = |grads: &[Tensor]| -> Vec<Vec<u32>> {
            grads.iter().map(|g| g.data().iter().map(|x| x.to_bits()).collect()).collect()
        };
        let (base_loss, base_grads) =
            loss_and_grads_pooled(&cfg, &params, &batch, &Pool::new(1), None).unwrap();
        let base_bits = bits(&base_grads);
        let mut bitexact = true;
        for &threads in &counts {
            let (l, g) =
                loss_and_grads_pooled(&cfg, &params, &batch, &Pool::new(threads), None).unwrap();
            bitexact &= l.to_bits() == base_loss.to_bits() && bits(&g) == base_bits;
        }
        assert!(bitexact, "serial vs parallel grads diverged — determinism bug");
        rep.value_row(
            &format!("{label} serial-vs-parallel grads bit-identical"),
            "bitexact",
            1.0,
            vec![("kind", Value::str("grads_bitexact"))],
        );

        let mut t1_ns = f64::NAN;
        for &threads in &counts {
            let pool = Pool::new(threads);
            let stats = bench_for(1, budget, || {
                loss_and_grads_pooled(&cfg, &params, &batch, &pool, None).unwrap()
            });
            if threads == 1 {
                t1_ns = stats.mean_ns;
            }
            let speedup = t1_ns / stats.mean_ns;
            rep.row(
                &format!("{label} loss_and_grads @{threads}t ({speedup:.2}x vs 1t)"),
                &stats,
                vec![
                    ("kind", Value::str("loss_and_grads_threads")),
                    ("params", Value::num(cfg.num_params() as f64)),
                    ("threads", Value::num(threads as f64)),
                    ("tokens_per_sec", Value::num(stats.per_second(tokens_per_step))),
                    ("speedup_vs_1t", Value::num(speedup)),
                ],
            );
        }
    }

    // ---- within-row backward scaling at batch 1 ---------------------------
    // a single-row batch gives the data-parallel fan-out nothing to split,
    // so it used to serialize on one core; backward_seq_pooled decomposes
    // the MHA backward into per-head tasks with a fixed-order merge
    // instead, keeping grads bit-identical at every thread count (asserted
    // below) while the step speeds up — the batch-1 fine-tune /
    // probe-train regime the series above cannot touch (DESIGN.md §17).
    {
        let cfg = ModelConfig {
            layers: 2, hidden: 64, heads: 4, k: 16, v: 16, mlp: 128, seq: 64, vocab: 256,
        };
        let label = "row   (2L h64 4H, batch 1)";
        let mut rng = Pcg32::seeded(6);
        let params = ParamStore::init(&cfg, &mut rng, 0.02);
        let batch = Batch::random(&cfg, 1, 7);
        let tokens_per_step = cfg.seq as f64;

        let mut counts = vec![1usize, 2, 4, env_threads()];
        counts.sort_unstable();
        counts.dedup();

        let bits = |grads: &[Tensor]| -> Vec<Vec<u32>> {
            grads.iter().map(|g| g.data().iter().map(|x| x.to_bits()).collect()).collect()
        };
        let (base_loss, base_grads) =
            loss_and_grads_pooled(&cfg, &params, &batch, &Pool::new(1), None).unwrap();
        let base_bits = bits(&base_grads);
        for &threads in &counts {
            let (l, g) =
                loss_and_grads_pooled(&cfg, &params, &batch, &Pool::new(threads), None).unwrap();
            assert!(
                l.to_bits() == base_loss.to_bits() && bits(&g) == base_bits,
                "within-row backward grads diverged at {threads} threads — determinism bug"
            );
        }
        rep.value_row(
            &format!("{label} per-head grads bit-identical"),
            "bitexact",
            1.0,
            vec![("kind", Value::str("backward_within_row_bitexact"))],
        );

        let mut t1_ns = f64::NAN;
        for &threads in &counts {
            let pool = Pool::new(threads);
            let stats = bench_for(1, budget, || {
                loss_and_grads_pooled(&cfg, &params, &batch, &pool, None).unwrap()
            });
            if threads == 1 {
                t1_ns = stats.mean_ns;
            }
            let speedup = t1_ns / stats.mean_ns;
            rep.row(
                &format!("{label} backward @{threads}t ({speedup:.2}x vs 1t)"),
                &stats,
                vec![
                    ("kind", Value::str("backward_within_row_threads")),
                    ("params", Value::num(cfg.num_params() as f64)),
                    ("threads", Value::num(threads as f64)),
                    ("tokens_per_sec", Value::num(stats.per_second(tokens_per_step))),
                    ("speedup_vs_1t", Value::num(speedup)),
                ],
            );
        }
    }

    // ---- kernel comparisons on training-shaped products --------------------
    // blocked vs naive matmul (forward + backward activation products)
    for (m, k, n) in [(64usize, 64usize, 256usize), (64, 256, 64), (128, 128, 128)] {
        let mut rng = Pcg32::seeded(3);
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let b = Tensor::randn(&[k, n], &mut rng, 1.0);
        let blocked = bench_for(2, budget, || a.matmul(&b).unwrap());
        let naive = bench_for(2, budget, || a.matmul_naive(&b).unwrap());
        let speedup = naive.mean_ns / blocked.mean_ns;
        rep.row(
            &format!("matmul {m}x{k}x{n} blocked ({speedup:.2}x vs naive)"),
            &blocked,
            vec![
                ("kind", Value::str("matmul_blocked")),
                ("naive_mean_ns", Value::num(naive.mean_ns)),
                ("speedup", Value::num(speedup)),
            ],
        );
    }

    // tiled matmul_bt vs naive (Q·Kᵀ scores and every dY·Wᵀ product):
    // seq×k×seq attention shape and seq×hidden×mlp gradient shape
    for (m, k, n) in [(64usize, 32usize, 64usize), (64, 128, 64), (128, 64, 128)] {
        let mut rng = Pcg32::seeded(4);
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let b = Tensor::randn(&[n, k], &mut rng, 1.0);
        assert_eq!(a.matmul_bt(&b).unwrap(), a.matmul_bt_naive(&b).unwrap());
        let tiled = bench_for(2, budget, || a.matmul_bt(&b).unwrap());
        let naive = bench_for(2, budget, || a.matmul_bt_naive(&b).unwrap());
        let speedup = naive.mean_ns / tiled.mean_ns;
        rep.row(
            &format!("matmul_bt {m}x{k}x{n} tiled ({speedup:.2}x vs naive)"),
            &tiled,
            vec![
                ("kind", Value::str("matmul_bt_tiled")),
                ("naive_mean_ns", Value::num(naive.mean_ns)),
                ("speedup", Value::num(speedup)),
            ],
        );
    }

    // blocked matmul_at vs naive (Aᵀ·dY weight-gradient products):
    // seq-summed hidden×mlp and hidden×vocab gradient shapes
    for (m, k, n) in [(64usize, 64usize, 128usize), (64, 128, 64), (64, 64, 256)] {
        let mut rng = Pcg32::seeded(5);
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let b = Tensor::randn(&[m, n], &mut rng, 1.0);
        assert_eq!(a.matmul_at(&b).unwrap(), a.matmul_at_naive(&b).unwrap());
        let blocked = bench_for(2, budget, || a.matmul_at(&b).unwrap());
        let naive = bench_for(2, budget, || a.matmul_at_naive(&b).unwrap());
        let speedup = naive.mean_ns / blocked.mean_ns;
        rep.row(
            &format!("matmul_at {m}x{k}x{n} blocked ({speedup:.2}x vs naive)"),
            &blocked,
            vec![
                ("kind", Value::str("matmul_at_blocked")),
                ("naive_mean_ns", Value::num(naive.mean_ns)),
                ("speedup", Value::num(speedup)),
            ],
        );
    }

    rep.flush();
}
