//! Native-backend training-step throughput across model sizes.
//!
//! Seeds the BENCH trajectory for the offline training path: per-size
//! step latency + tokens/sec through `autodiff::loss_and_grads` +
//! `Optimizer::step`, plus the blocked-vs-naive matmul kernel comparison
//! that justifies the `tensor::matmul` hot-path rework. Rows append to
//! `runs/bench.jsonl`.
//!
//! Run: `cargo bench --bench train_step` (no artifacts needed)

use texpand::autodiff::loss_and_grads;
use texpand::bench_util::{bench_for, Reporter};
use texpand::config::{ModelConfig, OptimKind, TrainConfig};
use texpand::data::Batch;
use texpand::json::Value;
use texpand::optim::Optimizer;
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::tensor::Tensor;

fn main() {
    let mut rep = Reporter::new("train_step (native backend)");
    let budget = std::time::Duration::from_millis(1500);

    // three sizes: the test tiny config, the tiny-schedule base, and the
    // default-schedule base
    let cases = [
        ("tiny  (1L h16)", ModelConfig { layers: 1, hidden: 16, heads: 2, k: 8, v: 8, mlp: 32, seq: 16, vocab: 64 }, 4usize),
        ("small (2L h32)", ModelConfig { layers: 2, hidden: 32, heads: 2, k: 16, v: 16, mlp: 64, seq: 32, vocab: 128 }, 4),
        ("base  (2L h64)", ModelConfig { layers: 2, hidden: 64, heads: 2, k: 32, v: 32, mlp: 128, seq: 64, vocab: 256 }, 8),
    ];

    for (label, cfg, batch_rows) in cases {
        let mut rng = Pcg32::seeded(1);
        let mut params = ParamStore::init(&cfg, &mut rng, 0.02);
        let mut opt = Optimizer::new(
            &TrainConfig { optimizer: OptimKind::Adam, ..Default::default() },
            &params,
        );
        let batch = Batch::random(&cfg, batch_rows, 2);
        let tokens_per_step = (batch_rows * cfg.seq) as f64;

        // grads only (the autodiff cost itself)
        let grad_stats = bench_for(1, budget, || loss_and_grads(&cfg, &params, &batch).unwrap());
        rep.row(
            &format!("{label} loss_and_grads"),
            &grad_stats,
            vec![
                ("kind", Value::str("loss_and_grads")),
                ("params", Value::num(cfg.num_params() as f64)),
                ("tokens_per_sec", Value::num(grad_stats.per_second(tokens_per_step))),
            ],
        );

        // full step: grads + Adam update
        let step_stats = bench_for(1, budget, || {
            let (loss, grads) = loss_and_grads(&cfg, &params, &batch).unwrap();
            opt.step(&mut params, &grads).unwrap();
            loss
        });
        let tps = step_stats.per_second(tokens_per_step);
        rep.row(
            &format!("{label} step ({tps:.0} tok/s)"),
            &step_stats,
            vec![
                ("kind", Value::str("step")),
                ("params", Value::num(cfg.num_params() as f64)),
                ("step_ms", Value::num(step_stats.mean_ms())),
                ("tokens_per_sec", Value::num(tps)),
            ],
        );
    }

    // blocked vs naive matmul on training-shaped products
    for (m, k, n) in [(64usize, 64usize, 256usize), (64, 256, 64), (128, 128, 128)] {
        let mut rng = Pcg32::seeded(3);
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let b = Tensor::randn(&[k, n], &mut rng, 1.0);
        let blocked = bench_for(2, budget, || a.matmul(&b).unwrap());
        let naive = bench_for(2, budget, || a.matmul_naive(&b).unwrap());
        let speedup = naive.mean_ns / blocked.mean_ns;
        rep.row(
            &format!("matmul {m}x{k}x{n} blocked ({speedup:.2}x vs naive)"),
            &blocked,
            vec![
                ("kind", Value::str("matmul_blocked")),
                ("naive_mean_ns", Value::num(naive.mean_ns)),
                ("speedup", Value::num(speedup)),
            ],
        );
    }

    rep.flush();
}
