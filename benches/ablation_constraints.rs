//! E6/E7 — constraint-minimality ablation (App. A notes + Related Work).
//!
//! The paper claims its constraint sets are *minimal*: every zero-init it
//! imposes is necessary, everything it leaves free is genuinely free, and
//! the two scaling factors (Eq. 19, Eq. 24) that "no known works consider"
//! are load-bearing. This bench measures the preservation error when each
//! knob is toggled independently:
//!
//!   constrained   — theorem followed exactly (expect ~1e-6)
//!   free-random   — unconstrained matrices randomized hard (expect ~1e-6:
//!                   the freedom is real)
//!   violated      — zero-init constraints broken (expect large)
//!   no-scaling    — zero-inits kept but scaling factors dropped (expect
//!                   large for attn/hidden, as only they carry factors)
//!
//! Run: `cargo bench --bench ablation_constraints`

use texpand::bench_util::Reporter;
use texpand::config::{GrowthOp, LayerPosition, ModelConfig};
use texpand::expand::{ExpandOptions, ExpansionPlan, Init};
use texpand::json::Value;
use texpand::model::{forward, max_logit_delta};
use texpand::params::ParamStore;
use texpand::rng::Pcg32;

fn main() {
    // O(1)-scale weights so attention scores are sensitive to the factors
    // (at tiny init the softmax is near-uniform and the ablation is vacuous)
    let cfg = ModelConfig { layers: 2, hidden: 32, heads: 2, k: 16, v: 16, mlp: 64, seq: 32, vocab: 64 };
    let mut rng = Pcg32::seeded(1);
    let params = ParamStore::init(&cfg, &mut rng, 0.25);
    let tokens: Vec<Vec<u32>> =
        (0..4).map(|_| (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u32).collect()).collect();
    let base = forward(&cfg, &params, &tokens).unwrap();

    let cases: Vec<(&str, Vec<GrowthOp>)> = vec![
        ("3.1 mlp", vec![GrowthOp::Mlp { p: 128 }]),
        ("3.2 heads_add", vec![GrowthOp::HeadsAdd { count: 1 }]),
        ("3.3 heads_expand", vec![GrowthOp::HeadsExpand { v: 32 }]),
        ("3.4 attn_expand", vec![GrowthOp::AttnExpand { k: 32 }]),
        ("3.5 hidden", vec![GrowthOp::Hidden { h: 48 }]),
        ("3.6 layers_add", vec![GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top }]),
    ];

    let variants: Vec<(&str, ExpandOptions)> = vec![
        ("constrained", ExpandOptions { init: Init::Normal(0.02), ..Default::default() }),
        ("free-random", ExpandOptions { init: Init::Normal(0.5), ..Default::default() }),
        (
            "violated",
            ExpandOptions { init: Init::Normal(0.5), zero_constrained: false, ..Default::default() },
        ),
        (
            "no-scaling",
            ExpandOptions { init: Init::Normal(0.02), scale_factors: false, ..Default::default() },
        ),
    ];

    let mut rep = Reporter::new("ablation_constraints (E6/E7)");
    println!(
        "{:<18} {:>14} {:>14} {:>14} {:>14}",
        "transform", "constrained", "free-random", "violated", "no-scaling"
    );
    for (name, ops) in &cases {
        let plan = ExpansionPlan::new(&cfg, ops.clone()).unwrap();
        let mut row = Vec::new();
        for (vname, opts) in &variants {
            let out = plan.materialize(&params, opts, &mut Pcg32::seeded(9)).unwrap();
            let d = max_logit_delta(&base, &forward(out.config(), &out, &tokens).unwrap()).unwrap();
            rep.value_row(&format!("{name} [{vname}]"), "max_abs_delta", d as f64, vec![
                ("transform", Value::str(*name)),
                ("variant", Value::str(*vname)),
            ]);
            row.push(d);
        }
        println!(
            "{:<18} {:>14.3e} {:>14.3e} {:>14.3e} {:>14.3e}",
            name, row[0], row[1], row[2], row[3]
        );
    }
    rep.flush();
    println!("\nexpected shape: columns 1-2 ~1e-6 (theorem + freedom), column 3 large for all,");
    println!("column 4 large ONLY for 3.4/3.5 (they alone carry the Eq.19/Eq.24 factors).");
}
