//! HTTP serve under overload: adaptive AIMD admission vs a static window,
//! measured by the `texpand loadgen` client fleet over real sockets.
//!
//! Method (DESIGN.md §18.4): calibrate the engine's closed-loop service
//! rate with a single client, then drive an open-loop arrival rate at
//! **8× that capacity** with 16 concurrent clients against two otherwise
//! identical servers:
//!
//! * `static-8x-overload` — a fixed wide window (no controller): every
//!   arrival is admitted, the decode batch grows to the full client
//!   fleet, and every stream's per-token latency inflates with it.
//! * `adaptive-8x-overload` — the AIMD controller with a 15% per-token
//!   latency-inflation SLO (`degrade_ratio = 1.15`): the window sawtooths
//!   around the largest batch that holds the SLO and the excess arrivals
//!   are shed with `429 Retry-After` instead of queued.
//!
//! Both runs land in `runs/bench.jsonl` as `kind:"serve_http_load"` rows;
//! the in-bench asserts are the acceptance gate — the adaptive server
//! must shed (`rejected > 0`) and bound client-observed p99 at or below
//! the static baseline's, while the static server sheds nothing and
//! degrades.
//!
//! Run: `cargo bench --bench serve_http_load`.
//! Env: `TEXPAND_BENCH_BUDGET_MS` < 300 shrinks the request budget for CI
//! smoke runs.

use std::sync::Arc;
use std::time::Duration;

use texpand::bench_util::{Reporter, Stats};
use texpand::config::ModelConfig;
use texpand::json::Value;
use texpand::obs::MetricsRegistry;
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::serve::http::{AimdOptions, HttpServer, HttpServerOptions};
use texpand::serve::loadgen::{self, LoadReport, LoadgenOptions};
use texpand::serve::{Engine, EngineOptions, KvTier};

const TOKENS: usize = 16;
const CLIENTS: usize = 16;
const OVERLOAD: f64 = 8.0;

fn cfg() -> ModelConfig {
    ModelConfig { layers: 2, hidden: 32, heads: 2, k: 16, v: 16, mlp: 64, seq: 64, vocab: 64 }
}

fn bind_server(aimd: AimdOptions) -> HttpServer {
    let params = ParamStore::init(&cfg(), &mut Pcg32::seeded(5), 0.02);
    // slots sized to the whole client fleet: the admission window is the
    // only throttle either server has
    let engine = Engine::with_registry(
        params,
        EngineOptions { max_slots: CLIENTS, parallel: false, kv_tier: KvTier::F32, ..Default::default() },
        &MetricsRegistry::new(),
    );
    let opts = HttpServerOptions { aimd, ..Default::default() };
    HttpServer::bind_with_registry(
        "127.0.0.1:0",
        engine,
        opts,
        Arc::new(MetricsRegistry::new()),
    )
    .expect("bind http server")
}

fn drive(server: &HttpServer, clients: usize, requests: usize, rate: f64) -> LoadReport {
    let opts = LoadgenOptions {
        addr: server.local_addr().to_string(),
        clients,
        requests,
        rate_per_sec: rate,
        tokens: TOKENS,
        prompt_mix: vec![4, 8],
        vocab: cfg().vocab,
        seed: 11,
        timeout: Duration::from_secs(60),
        ..Default::default()
    };
    loadgen::run(&opts).expect("loadgen run")
}

fn report_row(rep: &mut Reporter, case: &str, r: &LoadReport, rate: f64) {
    let stats = Stats {
        iters: r.completed + r.timeouts,
        mean_ns: r.mean_ms * 1e6,
        p50_ns: r.p50_ms * 1e6,
        p95_ns: r.p95_ms * 1e6,
        p99_ns: r.p99_ms * 1e6,
        min_ns: 0.0,
        max_ns: r.max_ms * 1e6,
    };
    rep.row(
        case,
        &stats,
        vec![
            ("kind", Value::str("serve_http_load")),
            ("mode", Value::str(r.mode)),
            ("sent", Value::num(r.sent as f64)),
            ("completed", Value::num(r.completed as f64)),
            ("rejected", Value::num(r.rejected as f64)),
            ("timeouts", Value::num(r.timeouts as f64)),
            ("errors", Value::num(r.errors as f64)),
            ("tokens_streamed", Value::num(r.tokens_streamed as f64)),
            ("tokens_per_sec", Value::num(r.tokens_per_sec)),
            ("rate_per_sec", Value::num(rate)),
            ("clients", Value::num(CLIENTS as f64)),
            ("overload_x", Value::num(OVERLOAD)),
        ],
    );
}

fn main() {
    let budget_ms: u64 = std::env::var("TEXPAND_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1500);
    let requests = if budget_ms < 300 { 24 } else { 64 };
    let mut rep = Reporter::new("serve_http_load");

    // ---- calibrate: single-client closed-loop service rate ----------------
    let server = bind_server(AimdOptions::default());
    let cal = drive(&server, 1, 8, 0.0);
    server.shutdown().expect("calibration shutdown");
    assert_eq!(cal.completed, 8, "calibration must stream clean");
    let service_rps = (cal.tokens_per_sec / TOKENS as f64).max(1.0);
    let rate = OVERLOAD * service_rps;
    rep.value_row(
        "calibration 1-client closed loop",
        "service_requests_per_sec",
        service_rps,
        vec![
            ("kind", Value::str("serve_http_load")),
            ("tokens_per_sec", Value::num(cal.tokens_per_sec)),
        ],
    );

    // ---- static baseline: wide fixed window, everything admitted ----------
    let wide = AimdOptions {
        initial_window: 64.0,
        min_window: 64.0,
        max_window: 64.0,
        adaptive: false,
        ..Default::default()
    };
    let server = bind_server(wide);
    let stat = drive(&server, CLIENTS, requests, rate);
    server.shutdown().expect("static shutdown");
    report_row(&mut rep, "static-8x-overload", &stat, rate);
    assert_eq!(stat.rejected, 0, "the static window never sheds");
    assert_eq!(stat.errors, 0, "static run must stream clean");

    // ---- adaptive: AIMD window with a 15% latency-inflation SLO -----------
    let slo = AimdOptions { degrade_ratio: 1.15, ..Default::default() };
    let server = bind_server(slo);
    let adap = drive(&server, CLIENTS, requests, rate);
    let (_, summary) = server.shutdown().expect("adaptive shutdown");
    report_row(&mut rep, "adaptive-8x-overload", &adap, rate);
    assert_eq!(adap.errors, 0, "adaptive run must stream clean");
    assert!(
        adap.rejected > 0,
        "adaptive admission must shed at {OVERLOAD}x overload (sent {}, rejected 0)",
        adap.sent
    );
    assert!(
        adap.p99_ms <= stat.p99_ms,
        "shedding must bound client p99: adaptive {:.2}ms > static {:.2}ms",
        adap.p99_ms,
        stat.p99_ms
    );
    println!(
        "overload {OVERLOAD}x @ {rate:.1} req/s: static p99 {:.2}ms (0 shed) vs adaptive p99 \
         {:.2}ms ({} shed, final window {})",
        stat.p99_ms, adap.p99_ms, adap.rejected, summary.final_window
    );

    rep.flush();
}
