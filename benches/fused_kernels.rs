//! Fused forward kernels vs their retained naive oracles, plus a compact
//! quantized-KV measurement (ISSUE 9, DESIGN.md §17).
//!
//! Three comparisons on model-shaped operands, each asserting the
//! kernel-policy contract in-bench before any timing is reported:
//!
//! * `rmsnorm_matmul` — the fused normalize-then-project kernel vs the
//!   unfused two-pass (`rmsnorm_matmul_naive`); bit-identical by policy.
//! * `attn_pv` — the register-tiled probs·V kernel vs the generic blocked
//!   `matmul`; bit-identical by construction (same ascending-k order).
//! * online softmax — the single-pass running-(max, norm) row pass vs the
//!   two-pass `softmax_rows`; the one *bounded* kernel (≤ 1e-6/element).
//!
//! The closing `kv_quant` rows decode one short greedy sequence on the
//! exact f32 cache and each compressed tier — half-precision f16 (~2×
//! fewer resident bytes) and block-quantized int8 (target ≥ 3×) —
//! reporting each tier's resident-bytes ratio and last-logits drift, so
//! CI gets a fast nonzero `kv_quant` signal per tier without running the
//! full serving bench. The int8 row is emitted last: ci.sh greps the
//! tail of the `kv_quant` series for a `bytes_ratio` ≥ 3 row.
//!
//! Rows append to `runs/bench.jsonl` with `kind` `fused_kernels` /
//! `kv_quant`. Run: `cargo bench --bench fused_kernels`.
//! Env: `TEXPAND_BENCH_BUDGET_MS` shrinks the per-case budget (default
//! 1500) for CI smoke runs.

use texpand::bench_util::{bench_for, Reporter};
use texpand::config::ModelConfig;
use texpand::json::Value;
use texpand::model::forward_incremental;
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::serve::{F16KvCache, KvCache, QuantKvCache};
use texpand::tensor::{softmax_rows, softmax_rows_online, Tensor};

fn main() {
    let mut rep = Reporter::new("fused_kernels");
    let budget_ms: u64 = std::env::var("TEXPAND_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1500);
    let budget = std::time::Duration::from_millis(budget_ms);

    // ---- rmsnorm_matmul: fused normalize+project vs unfused two-pass ------
    // (seq × hidden) · (hidden × out) at block-boundary and ragged shapes
    for (seq, hidden, out) in [(64usize, 64usize, 128usize), (64, 128, 256), (48, 96, 144)] {
        let mut rng = Pcg32::seeded(11);
        let x = Tensor::randn(&[seq, hidden], &mut rng, 1.0);
        let g = Tensor::randn(&[hidden], &mut rng, 0.5);
        let w = Tensor::randn(&[hidden, out], &mut rng, 0.5);
        // kernel policy: the fused path must be bit-identical to the oracle
        assert_eq!(
            x.rmsnorm_matmul(&g, &w).unwrap(),
            x.rmsnorm_matmul_naive(&g, &w).unwrap(),
            "fused rmsnorm_matmul diverged from its naive oracle"
        );
        let fused = bench_for(2, budget, || x.rmsnorm_matmul(&g, &w).unwrap());
        let naive = bench_for(2, budget, || x.rmsnorm_matmul_naive(&g, &w).unwrap());
        let speedup = naive.mean_ns / fused.mean_ns;
        rep.row(
            &format!("rmsnorm_matmul {seq}x{hidden}x{out} fused ({speedup:.2}x vs unfused)"),
            &fused,
            vec![
                ("kind", Value::str("fused_kernels")),
                ("kernel", Value::str("rmsnorm_matmul")),
                ("naive_mean_ns", Value::num(naive.mean_ns)),
                ("speedup", Value::num(speedup)),
            ],
        );
    }

    // ---- attn_pv: register-tiled probs·V vs the generic blocked matmul ----
    // (seq × seq) probability rows against (seq × v) value tiles
    for (seq, v) in [(64usize, 16usize), (64, 32), (128, 32)] {
        let mut rng = Pcg32::seeded(12);
        let mut probs = Tensor::randn(&[seq, seq], &mut rng, 1.0);
        softmax_rows_online(&mut probs);
        let vals = Tensor::randn(&[seq, v], &mut rng, 0.5);
        assert_eq!(
            probs.attn_pv(&vals).unwrap(),
            probs.attn_pv_naive(&vals).unwrap(),
            "tiled attn_pv diverged from its naive oracle"
        );
        let tiled = bench_for(2, budget, || probs.attn_pv(&vals).unwrap());
        let naive = bench_for(2, budget, || probs.attn_pv_naive(&vals).unwrap());
        let speedup = naive.mean_ns / tiled.mean_ns;
        rep.row(
            &format!("attn_pv {seq}x{seq}x{v} tiled ({speedup:.2}x vs naive)"),
            &tiled,
            vec![
                ("kind", Value::str("fused_kernels")),
                ("kernel", Value::str("attn_pv")),
                ("naive_mean_ns", Value::num(naive.mean_ns)),
                ("speedup", Value::num(speedup)),
            ],
        );
    }

    // ---- online softmax: single-pass running-(max, norm) vs two-pass ------
    // the one bounded (not bit-exact) kernel: check the documented bound
    for seq in [64usize, 128] {
        let mut rng = Pcg32::seeded(13);
        let scores = Tensor::randn(&[seq, seq], &mut rng, 2.0);
        let mut online = scores.clone();
        softmax_rows_online(&mut online);
        let mut twopass = scores.clone();
        softmax_rows(&mut twopass);
        let mut drift = 0.0f32;
        for (a, b) in online.data().iter().zip(twopass.data()) {
            drift = drift.max((a - b).abs());
        }
        assert!(drift <= 1e-5, "online softmax drift {drift:e} exceeds the documented bound");
        let one_pass = bench_for(2, budget, || {
            let mut t = scores.clone();
            softmax_rows_online(&mut t);
            t
        });
        let two_pass = bench_for(2, budget, || {
            let mut t = scores.clone();
            softmax_rows(&mut t);
            t
        });
        let speedup = two_pass.mean_ns / one_pass.mean_ns;
        rep.row(
            &format!("softmax {seq}x{seq} online ({speedup:.2}x vs two-pass, drift {drift:.1e})"),
            &one_pass,
            vec![
                ("kind", Value::str("fused_kernels")),
                ("kernel", Value::str("softmax_online")),
                ("naive_mean_ns", Value::num(two_pass.mean_ns)),
                ("speedup", Value::num(speedup)),
                ("max_drift", Value::num(drift as f64)),
            ],
        );
    }

    // ---- compact compressed-KV rows, one per tier -------------------------
    // one short decode per tier at k=v=16 (the smallest width where the
    // int8 tier clears 3×); drift is measured on the pending last-logits,
    // the quantity a hot-swap recomputes. int8 goes last so ci.sh's
    // tail-of-series grep always sees a `bytes_ratio` ≥ 3 row.
    {
        let cfg = ModelConfig {
            layers: 2, hidden: 32, heads: 2, k: 16, v: 16, mlp: 64, seq: 32, vocab: 64,
        };
        let mut rng = Pcg32::seeded(14);
        let params = ParamStore::init(&cfg, &mut rng, 0.05);
        let tokens: Vec<u32> = (0..16).map(|_| rng.below(cfg.vocab) as u32).collect();
        let mut exact = KvCache::new(&cfg);
        let mut half = F16KvCache::new(&cfg);
        let mut quant = QuantKvCache::new(&cfg);
        for &t in &tokens {
            forward_incremental(&cfg, &params, &mut exact, t).unwrap();
            forward_incremental(&cfg, &params, &mut half, t).unwrap();
            forward_incremental(&cfg, &params, &mut quant, t).unwrap();
        }
        let le = exact.last_logits(&params).unwrap();
        let drift_against = |lt: &texpand::tensor::Tensor| {
            let mut drift = 0.0f32;
            for (a, b) in le.data().iter().zip(lt.data()) {
                drift = drift.max((a - b).abs());
            }
            drift
        };
        let f32_bytes = exact.kv_resident_bytes();

        let lh = half.last_logits(&params).unwrap();
        let drift = drift_against(&lh);
        let ratio = f32_bytes as f64 / half.kv_resident_bytes() as f64;
        assert!(ratio >= 1.9, "f16 KV bytes ratio {ratio:.2} below the 2x target");
        rep.value_row(
            &format!("f16 kv bytes ratio (drift {drift:.1e})"),
            "bytes_ratio",
            ratio,
            vec![
                ("kind", Value::str("kv_quant")),
                ("tier", Value::str("f16")),
                ("kv_bytes_per_seq", Value::num(half.kv_resident_bytes() as f64)),
                ("f32_kv_bytes_per_seq", Value::num(f32_bytes as f64)),
                ("logit_drift", Value::num(drift as f64)),
            ],
        );

        let lq = quant.last_logits(&params).unwrap();
        let drift = drift_against(&lq);
        let ratio = f32_bytes as f64 / quant.kv_resident_bytes() as f64;
        assert!(ratio >= 3.0, "quant KV bytes ratio {ratio:.2} below the 3x target");
        rep.value_row(
            &format!("quant kv bytes ratio (drift {drift:.1e})"),
            "bytes_ratio",
            ratio,
            vec![
                ("kind", Value::str("kv_quant")),
                ("tier", Value::str("int8")),
                ("kv_bytes_per_seq", Value::num(quant.kv_resident_bytes() as f64)),
                ("f32_kv_bytes_per_seq", Value::num(f32_bytes as f64)),
                ("logit_drift", Value::num(drift as f64)),
            ],
        );
    }

    rep.flush();
}
