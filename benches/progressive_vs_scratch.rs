//! E3 — the headline experiment: progressive growth vs from-scratch.
//!
//! Two runs with the SAME total optimizer steps and the SAME data stream:
//!
//!   progressive — the shipped 4-stage growth schedule (small → large via
//!                 the six function-preserving expansions);
//!   scratch     — the final architecture trained from random init for the
//!                 same step count.
//!
//! Reported per run: final eval loss on a shared held-out probe, wall-clock
//! time, and a hardware-independent compute proxy (Σ steps·params·tokens,
//! the 6ND-style accounting the paper's §1 cost argument uses). The
//! paper-shape expectation is NOT that progressive wins on loss at equal
//! steps — it is that it reaches comparable loss at a fraction of the
//! compute, because early steps run on a ~5x smaller model.
//!
//! Backends: runs **fully offline on the native autodiff backend by
//! default** (no artifacts — the manifest is synthesized from the
//! schedule, and batch rows data-parallelize over `TEXPAND_THREADS`).
//! Set `TEXPAND_E3_BACKEND=pjrt` to run against AOT artifacts instead
//! (needs `make artifacts`).
//!
//! On the native backend the bench also appends a `policy_compare` series
//! to `runs/bench.jsonl`: fixed vs plateau vs greedy growth policies on
//! the same schedule at the same step budget (matched compute), reporting
//! final eval loss, compute proxy, and how many expansions each committed.
//!
//! Env: TEXPAND_E3_BACKEND  native|pjrt    (default native)
//!      TEXPAND_E3_SCHEDULE schedule path  (default configs/growth_default.json)
//!      TEXPAND_E3_SCALE    step scale     (default 1.0)
//! Run: `cargo bench --bench progressive_vs_scratch`

use texpand::autodiff::{ExecBackend, NativeBackend};
use texpand::bench_util::Reporter;
use texpand::config::{GrowthSchedule, PolicyKind, TrainConfig};
use texpand::coordinator::{Coordinator, CoordinatorOptions};
use texpand::data::{Batcher, CorpusKind};
use texpand::json::Value;
use texpand::metrics::{RunLogger, Timer};
use texpand::optim::Optimizer;
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::runtime::{Manifest, Runtime};
use texpand::train::{eval_loss, train_stage, TrainState};

fn make_backend(kind: &str) -> Box<dyn ExecBackend> {
    match kind {
        "native" => Box::new(NativeBackend::new()),
        "pjrt" => Box::new(Runtime::cpu().expect("PJRT runtime")),
        other => panic!("TEXPAND_E3_BACKEND must be native|pjrt, got '{other}'"),
    }
}

/// Hardware-independent compute proxy over a run's segments: Σ steps ×
/// params × tokens (segments record their own param counts, so this is
/// correct for adaptive policies whose architectures differ from the
/// schedule's stage table).
fn run_compute(summary: &texpand::coordinator::RunSummary, schedule: &GrowthSchedule) -> f64 {
    let seq = schedule.stages[0].config.seq; // seq never grows
    summary
        .stages
        .iter()
        .map(|rep| rep.steps_run as f64 * rep.params as f64 * (schedule.batch * seq) as f64)
        .sum()
}

fn main() {
    let backend_kind =
        std::env::var("TEXPAND_E3_BACKEND").unwrap_or_else(|_| "native".to_string());
    // validate before the manifest branch so a typo'd value reports as
    // such instead of dying in the artifact loader's "run `make
    // artifacts`" message
    assert!(
        backend_kind == "native" || backend_kind == "pjrt",
        "TEXPAND_E3_BACKEND must be native|pjrt, got '{backend_kind}'"
    );
    let schedule_path = std::env::var("TEXPAND_E3_SCHEDULE")
        .unwrap_or_else(|_| "configs/growth_default.json".to_string());
    let scale: f64 = std::env::var("TEXPAND_E3_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let schedule = GrowthSchedule::load(&schedule_path).unwrap();
    let manifest = match backend_kind.as_str() {
        "native" => Manifest::from_schedule(&schedule),
        _ => Manifest::load("artifacts", "manifest.json").expect("run `make artifacts`"),
    };
    let tcfg = TrainConfig { log_every: 10_000, ..Default::default() };
    let corpus = CorpusKind::MarkovText;
    let corpus_len = 200_000;
    let mut rep = Reporter::new(format!("progressive_vs_scratch (E3, {backend_kind})"));

    // ---- progressive ------------------------------------------------------
    let timer = Timer::start();
    let mut coord = Coordinator::new(
        schedule.clone(),
        manifest.clone(),
        make_backend(&backend_kind),
        tcfg.clone(),
        CoordinatorOptions {
            steps_scale: scale,
            save_checkpoints: false,
            corpus,
            corpus_len,
            ..Default::default()
        },
    )
    .unwrap();
    let summary = coord.run("runs", "e3-progressive").unwrap();
    let prog_wall = timer.secs();
    let total_steps: usize = summary.stages.iter().map(|s| s.steps_run).sum();
    let prog_compute = run_compute(&summary, &schedule);

    // ---- scratch (final architecture, same steps, same data) ---------------
    let timer = Timer::start();
    let final_stage_name = schedule.stages.last().unwrap().name.clone();
    let final_cfg = *schedule.final_config();
    let mut backend = make_backend(&backend_kind);
    let exec = backend.load_stage(&manifest, &final_stage_name).unwrap();
    let mut rng = Pcg32::seeded(tcfg.seed);
    let mut params = ParamStore::init(&final_cfg, &mut rng, 0.02);
    let mut opt = Optimizer::new(&tcfg, &params);
    let mut batcher = Batcher::from_corpus(
        corpus,
        corpus_len,
        final_cfg.vocab,
        final_cfg.seq,
        schedule.batch,
        tcfg.seed ^ 0xC0DE, // same corpus stream as the coordinator uses
    )
    .unwrap();
    let mut logger = RunLogger::create("runs", "e3-scratch").unwrap().quiet();
    let mut state = TrainState::new();
    let scratch_report = train_stage(
        backend.as_ref(),
        &exec,
        &mut params,
        &mut opt,
        &mut batcher,
        &tcfg,
        &mut logger,
        &mut state,
        total_steps,
    )
    .unwrap();
    let scratch_wall = timer.secs();
    let probe = batcher.probe(tcfg.seed ^ 0xE7A1);
    let scratch_eval = eval_loss(backend.as_ref(), &exec, &params, &probe).unwrap();
    let scratch_compute =
        total_steps as f64 * final_cfg.num_params() as f64 * (schedule.batch * final_cfg.seq) as f64;

    // ---- report -------------------------------------------------------------
    println!("\n{:<14} {:>8} {:>12} {:>12} {:>14} {:>10}", "run", "steps", "eval loss", "wall (s)", "compute", "rel");
    let rel = prog_compute / scratch_compute;
    println!(
        "{:<14} {:>8} {:>12.4} {:>12.1} {:>14.3e} {:>10.2}",
        "progressive", total_steps, summary.final_eval_loss, prog_wall, prog_compute, rel
    );
    println!(
        "{:<14} {:>8} {:>12.4} {:>12.1} {:>14.3e} {:>10.2}",
        "scratch", total_steps, scratch_eval, scratch_wall, scratch_compute, 1.0
    );
    let backend_field = || ("backend", Value::str(backend_kind.clone()));
    rep.value_row("progressive final eval loss", "loss", f64::from(summary.final_eval_loss), vec![
        backend_field(),
        ("steps", Value::num(total_steps as f64)),
        ("compute", Value::num(prog_compute)),
        ("wall_s", Value::num(prog_wall)),
    ]);
    rep.value_row("scratch final eval loss", "loss", f64::from(scratch_eval), vec![
        backend_field(),
        ("steps", Value::num(total_steps as f64)),
        ("compute", Value::num(scratch_compute)),
        ("wall_s", Value::num(scratch_wall)),
    ]);
    rep.value_row("progressive/scratch compute ratio", "ratio", rel, vec![backend_field()]);
    rep.value_row(
        "boundary max |Δloss| (continuity)",
        "delta",
        summary
            .boundaries
            .iter()
            .map(|b| f64::from((b.loss_after - b.loss_before).abs()))
            .fold(0.0, f64::max),
        vec![backend_field()],
    );

    // ---- policy compare: fixed vs plateau vs greedy at matched compute ------
    // Same schedule, same step budget, same data stream; only the growth
    // *decisions* differ. Native only: adaptive policies synthesize
    // architectures the AOT manifest never compiled.
    if backend_kind == "native" {
        println!("\n{:<14} {:>8} {:>12} {:>12} {:>14} {:>6}", "policy", "steps", "eval loss", "wall (s)", "compute", "grows");
        let mut policy_row = |name: &str, s: &texpand::coordinator::RunSummary, wall: f64| {
            let compute = run_compute(s, &schedule);
            println!(
                "{:<14} {:>8} {:>12.4} {:>12.1} {:>14.3e} {:>6}",
                name,
                s.total_steps,
                s.final_eval_loss,
                wall,
                compute,
                s.boundaries.len()
            );
            rep.value_row(&format!("policy_compare {name}"), "loss", f64::from(s.final_eval_loss), vec![
                ("series", Value::str("policy_compare")),
                ("policy", Value::str(name)),
                ("backend", Value::str("native")),
                ("steps", Value::num(s.total_steps as f64)),
                ("compute", Value::num(compute)),
                ("expansions", Value::num(s.boundaries.len() as f64)),
                ("wall_s", Value::num(wall)),
            ]);
        };
        policy_row("fixed", &summary, prog_wall);
        for kind in [PolicyKind::Plateau, PolicyKind::Greedy] {
            let mut pcfg = schedule.policy.clone();
            pcfg.kind = kind;
            let mut coord = Coordinator::new(
                schedule.clone(),
                manifest.clone(),
                make_backend("native"),
                tcfg.clone(),
                CoordinatorOptions {
                    steps_scale: scale,
                    save_checkpoints: false,
                    corpus,
                    corpus_len,
                    ..Default::default()
                },
            )
            .unwrap();
            let mut policy =
                texpand::growth::build_policy(&schedule, scale, &pcfg, tcfg.seed);
            let timer = Timer::start();
            let run_name = format!("e3-policy-{}", kind.name());
            let s = coord.run_with_policy("runs", &run_name, policy.as_mut()).unwrap();
            policy_row(kind.name(), &s, timer.secs());
        }
    }
    rep.flush();
    println!(
        "\nshape check: progressive used {:.0}% of scratch compute (wall {:.0}%), with",
        100.0 * rel,
        100.0 * prog_wall / scratch_wall
    );
    println!("loss gap {:+.4} nats; every boundary loss-continuous (function preservation).",
        summary.final_eval_loss - scratch_eval);
    println!("scratch first-step loss {:.3} vs progressive final-stage entry {:.3}: the grown model",
        scratch_report.first_loss,
        summary.stages.last().unwrap().first_loss);
    println!("never revisits the random-init regime — the paper's knowledge-reuse claim.");
}
