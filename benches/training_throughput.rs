//! E5 — per-stage training throughput (the paper's §1 cost argument).
//!
//! The economic case for progressive growth is that early training steps
//! run on a *small* architecture. This bench measures step latency and
//! tokens/sec for every stage of the shipped schedule through the full
//! PJRT path, plus the relative cost of each stage — the numbers that make
//! the E3 compute-to-loss comparison concrete.
//!
//! Run: `cargo bench --bench training_throughput` (needs `make artifacts`)

use texpand::bench_util::{bench, Reporter};
use texpand::json::Value;
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::runtime::{Manifest, Runtime};

fn main() {
    let manifest = Manifest::load("artifacts", "manifest.json")
        .expect("run `make artifacts` before this bench");
    let mut rt = Runtime::cpu().expect("pjrt cpu client");
    let mut rep = Reporter::new("training_throughput (per stage)");

    let mut stage0_mean = None;
    for stage_meta in &manifest.stages {
        let stage = rt.load_stage(&manifest, &stage_meta.name).unwrap();
        let cfg = stage.meta.config;
        let mut rng = Pcg32::seeded(7);
        let params = ParamStore::init(&cfg, &mut rng, 0.02);
        let batch = {
            let mut rng = Pcg32::seeded(8);
            let row = |rng: &mut Pcg32| (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u32).collect();
            texpand::data::Batch {
                tokens: (0..manifest.batch).map(|_| row(&mut rng)).collect(),
                targets: (0..manifest.batch).map(|_| row(&mut rng)).collect(),
            }
        };
        let tokens_per_step = (manifest.batch * cfg.seq) as f64;

        let fwd_stats = bench(2, 10, || rt.forward(&stage, &params, &batch.tokens).unwrap());
        rep.row(
            &format!("{} fwd  ({} params)", stage_meta.name, stage_meta.num_params),
            &fwd_stats,
            vec![("stage", Value::str(stage_meta.name.clone())), ("kind", Value::str("fwd"))],
        );

        let step_stats = bench(2, 10, || rt.step(&stage, &params, &batch).unwrap());
        let tps = step_stats.per_second(tokens_per_step);
        rep.row(
            &format!("{} step ({:.0} tok/s)", stage_meta.name, tps),
            &step_stats,
            vec![
                ("stage", Value::str(stage_meta.name.clone())),
                ("kind", Value::str("step")),
                ("tokens_per_sec", Value::num(tps)),
                ("params", Value::num(stage_meta.num_params as f64)),
            ],
        );
        if stage_meta.name == "stage0" {
            stage0_mean = Some(step_stats.mean_ns);
        }
        if let Some(s0) = stage0_mean {
            rep.value_row(
                &format!("{} relative step cost vs stage0", stage_meta.name),
                "ratio",
                step_stats.mean_ns / s0,
                vec![("stage", Value::str(stage_meta.name.clone()))],
            );
        }
    }
    rep.flush();
    println!("\npaper-shape expectation: step cost grows monotonically with stage size,");
    println!("so front-loading steps onto small stages buys the E3 compute savings.");
}
