//! Serving decode latency: KV-cached incremental decode vs the KV-less
//! full-re-forward oracle, batched vs sequential engine throughput, and
//! the int8-quantized KV tier vs exact f32.
//!
//! Acceptance target (ISSUE 1): KV-cached decode ≥ 3× tokens/sec over full
//! re-forward at the largest benchmarked stage. The asymptotics are on the
//! cache's side — a full re-forward pays O(seq²) attention per token over
//! the whole (padded) window, the incremental path one position — so the
//! ratio *grows* with stage size; the bench prints it per stage.
//!
//! ISSUE 9 adds the `kv_quant` series: per stage, a greedy decode on the
//! block-quantized int8 cache next to the exact f32 one, reporting
//! `kv_bytes_per_seq` for both, the resident-bytes ratio (target ≥ 3×),
//! and the fraction of greedy tokens that match the exact tier. Every
//! timed row also carries its `kv_bytes_per_seq` and p99 latency.
//!
//! Run: `cargo bench --bench serving_latency`

use texpand::bench_util::{bench, Reporter, Stats};
use texpand::config::ModelConfig;
use texpand::generate::{generate_ref, sample_from_logits, Sampler};
use texpand::json::Value;
use texpand::model::forward_incremental;
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::serve::{Engine, EngineOptions, KvCache, KvCacheImpl, KvStorage, QuantKvCache};

fn stages() -> Vec<(&'static str, ModelConfig)> {
    vec![
        (
            "small (~0.1M)",
            ModelConfig { layers: 2, hidden: 32, heads: 2, k: 16, v: 16, mlp: 64, seq: 64, vocab: 128 },
        ),
        (
            "medium (~0.5M)",
            ModelConfig { layers: 4, hidden: 64, heads: 4, k: 16, v: 16, mlp: 128, seq: 64, vocab: 128 },
        ),
        (
            "large (~2M)",
            ModelConfig { layers: 4, hidden: 128, heads: 4, k: 32, v: 32, mlp: 256, seq: 128, vocab: 128 },
        ),
    ]
}

fn greedy() -> Sampler {
    Sampler { temperature: 0.0, top_k: None, seed: 0 }
}

fn prompt(cfg: &ModelConfig, len: usize, seed: u64) -> Vec<u32> {
    let mut rng = Pcg32::seeded(seed);
    (0..len).map(|_| rng.below(cfg.vocab) as u32).collect()
}

/// Raw KV-cached greedy decode of one sequence (the serving decode path
/// without engine setup, so the timing is symmetric with `generate_ref`).
fn kv_decode(params: &ParamStore, prompt: &[u32], new_tokens: usize) {
    let cfg = *params.config();
    let mut cache = KvCache::new(&cfg);
    let mut logits = None;
    for &t in prompt {
        logits = Some(forward_incremental(&cfg, params, &mut cache, t).expect("prime"));
    }
    let mut rng = Pcg32::seeded(0);
    let mut last = logits.expect("non-empty prompt");
    for _ in 0..new_tokens - 1 {
        let next = sample_from_logits(last.row(0), &greedy(), &mut rng);
        last = forward_incremental(&cfg, params, &mut cache, next).expect("decode");
    }
    sample_from_logits(last.row(0), &greedy(), &mut rng);
}

/// Greedy decode over any KV storage tier, returning the generated
/// tokens and the cache's resident K/V bytes at the end — the
/// token-match and bytes comparisons between tiers read both sides
/// through this one loop.
fn decode_tokens<S: KvStorage>(
    params: &ParamStore,
    cache: &mut KvCacheImpl<S>,
    prompt: &[u32],
    new_tokens: usize,
) -> (Vec<u32>, usize) {
    let cfg = *params.config();
    let mut last = None;
    for &t in prompt {
        last = Some(forward_incremental(&cfg, params, cache, t).expect("prime"));
    }
    let mut rng = Pcg32::seeded(0);
    let mut logits = last.expect("non-empty prompt");
    let mut out = Vec::with_capacity(new_tokens);
    for _ in 0..new_tokens {
        let next = sample_from_logits(logits.row(0), &greedy(), &mut rng);
        out.push(next);
        logits = forward_incremental(&cfg, params, cache, next).expect("decode");
    }
    (out, cache.kv_resident_bytes())
}

/// Submit `prompts` and drain the engine. Callers time this with one
/// `make_engine` per iteration on *both* sides of a comparison, so engine
/// setup (params clone + probe synthesis) cancels out instead of biasing
/// one side.
fn engine_pass(eng: &mut Engine, prompts: &[Vec<u32>], new_tokens: usize) {
    for p in prompts {
        eng.submit(p.clone(), new_tokens, greedy()).expect("submit");
    }
    eng.run_until_idle().expect("serve");
}

fn make_engine(params: &ParamStore, slots: usize, parallel: bool) -> Engine {
    Engine::new(params.clone(), EngineOptions { max_slots: slots, parallel, ..Default::default() })
}

fn main() {
    let mut rep = Reporter::new("serving_latency");
    let new_tokens = 24;
    let batch = 4;

    for (stage_name, cfg) in stages() {
        let mut rng = Pcg32::seeded(1);
        let params = ParamStore::init(&cfg, &mut rng, 0.02);
        let n_params = params.num_scalars();
        let one_prompt = vec![prompt(&cfg, 8, 2)];

        // --- single-sequence decode: KV cache vs full re-forward ---------
        let (f32_tokens, f32_bytes) = {
            let mut cache = KvCache::new(&cfg);
            decode_tokens(&params, &mut cache, &one_prompt[0], new_tokens)
        };
        let kv: Stats = bench(1, 3, || kv_decode(&params, &one_prompt[0], new_tokens));
        rep.row(
            &format!("{stage_name:<14} kv-cached decode x{new_tokens}"),
            &kv,
            vec![
                ("params", Value::num(n_params as f64)),
                ("tokens_per_sec", Value::num(kv.per_second(new_tokens as f64))),
                ("kv_bytes_per_seq", Value::num(f32_bytes as f64)),
            ],
        );
        let full: Stats =
            bench(1, 3, || generate_ref(&params, &one_prompt, new_tokens, &greedy()).expect("decode"));
        rep.row(
            &format!("{stage_name:<14} full re-forward x{new_tokens}"),
            &full,
            vec![
                ("params", Value::num(n_params as f64)),
                ("tokens_per_sec", Value::num(full.per_second(new_tokens as f64))),
            ],
        );
        let speedup = full.mean_ns / kv.mean_ns;
        rep.value_row(
            &format!("{stage_name:<14} kv speedup (x)"),
            "speedup",
            speedup,
            vec![("params", Value::num(n_params as f64))],
        );

        // --- quantized KV tier: resident bytes and greedy fidelity -------
        // same decode loop on both tiers; the ratio row is what ci.sh
        // greps for (target ≥ 3× smaller, DESIGN.md §17)
        let (q_tokens, q_bytes) = {
            let mut cache = QuantKvCache::new(&cfg);
            decode_tokens(&params, &mut cache, &one_prompt[0], new_tokens)
        };
        let matched =
            f32_tokens.iter().zip(&q_tokens).filter(|(a, b)| a == b).count();
        let quant: Stats = bench(1, 3, || {
            let mut cache = QuantKvCache::new(&cfg);
            decode_tokens(&params, &mut cache, &one_prompt[0], new_tokens)
        });
        let bytes_ratio = f32_bytes as f64 / q_bytes as f64;
        rep.row(
            &format!("{stage_name:<14} quant-kv decode x{new_tokens} ({bytes_ratio:.2}x fewer bytes)"),
            &quant,
            vec![
                ("kind", Value::str("kv_quant")),
                ("params", Value::num(n_params as f64)),
                ("tokens_per_sec", Value::num(quant.per_second(new_tokens as f64))),
                ("kv_bytes_per_seq", Value::num(q_bytes as f64)),
                ("f32_kv_bytes_per_seq", Value::num(f32_bytes as f64)),
                ("bytes_ratio", Value::num(bytes_ratio)),
                ("greedy_match_frac", Value::num(matched as f64 / new_tokens as f64)),
            ],
        );

        // --- batched vs sequential engine throughput ---------------------
        // one engine each side (built untimed), so the comparison isolates
        // slot parallelism: `slots=1` drains the same queue sequentially
        let prompts: Vec<Vec<u32>> = (0..batch).map(|i| prompt(&cfg, 8, 10 + i as u64)).collect();
        let total = (batch * new_tokens) as f64;
        let batched: Stats = bench(1, 3, || {
            let mut eng = make_engine(&params, batch, true);
            engine_pass(&mut eng, &prompts, new_tokens);
        });
        rep.row(
            &format!("{stage_name:<14} batched x{batch} (parallel slots)"),
            &batched,
            vec![("tokens_per_sec", Value::num(batched.per_second(total)))],
        );
        let sequential: Stats = bench(1, 3, || {
            let mut eng = make_engine(&params, 1, false);
            engine_pass(&mut eng, &prompts, new_tokens);
        });
        rep.row(
            &format!("{stage_name:<14} sequential x{batch} (1 slot)"),
            &sequential,
            vec![("tokens_per_sec", Value::num(sequential.per_second(total)))],
        );
        rep.value_row(
            &format!("{stage_name:<14} batching speedup (x)"),
            "speedup",
            sequential.mean_ns / batched.mean_ns,
            vec![],
        );
    }
    rep.flush();
    println!("\ntarget (ISSUE 1): kv speedup >= 3x at the largest stage.");
    println!("target (ISSUE 9): quant kv >= 3x fewer resident bytes per sequence.");
}
