//! Expansion-surgery latency (supports DESIGN.md §Perf L3 target: surgery
//! ≤ 100 ms at ~10 M params — it runs once per boundary, but a framework
//! that stalls the trainer for seconds at every growth point would poison
//! the progressive-training economics the paper motivates).
//!
//! Benchmarks each of the six transformations at three model scales,
//! plus Adam moment surgery (which doubles the work).
//!
//! Run: `cargo bench --bench expansion_ops`

use texpand::bench_util::{bench, Reporter};
use texpand::config::{GrowthOp, LayerPosition, ModelConfig, OptimKind, TrainConfig};
use texpand::expand::{ExpandOptions, ExpansionPlan};
use texpand::json::Value;
use texpand::optim::Optimizer;
use texpand::params::ParamStore;
use texpand::rng::Pcg32;

fn scales() -> Vec<(&'static str, ModelConfig)> {
    vec![
        (
            "small (~0.4M)",
            ModelConfig { layers: 4, hidden: 96, heads: 3, k: 32, v: 32, mlp: 256, seq: 64, vocab: 256 },
        ),
        (
            "medium (~3M)",
            ModelConfig { layers: 6, hidden: 256, heads: 4, k: 64, v: 64, mlp: 1024, seq: 128, vocab: 256 },
        ),
        (
            "large (~11M)",
            ModelConfig { layers: 8, hidden: 512, heads: 8, k: 64, v: 64, mlp: 2048, seq: 128, vocab: 256 },
        ),
    ]
}

fn ops_for(cfg: &ModelConfig) -> Vec<(&'static str, GrowthOp)> {
    vec![
        ("mlp x2", GrowthOp::Mlp { p: cfg.mlp * 2 }),
        ("heads_add +1", GrowthOp::HeadsAdd { count: 1 }),
        ("heads_expand x2", GrowthOp::HeadsExpand { v: cfg.v * 2 }),
        ("attn_expand x2", GrowthOp::AttnExpand { k: cfg.k * 2 }),
        ("hidden x1.5", GrowthOp::Hidden { h: cfg.hidden * 3 / 2 }),
        ("layers_add +1", GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top }),
    ]
}

fn main() {
    let mut rep = Reporter::new("expansion_ops");
    let opts = ExpandOptions::default();
    for (scale_name, cfg) in scales() {
        let mut rng = Pcg32::seeded(1);
        let params = ParamStore::init(&cfg, &mut rng, 0.02);
        let n_params = params.num_scalars();
        for (op_name, op) in ops_for(&cfg) {
            let plan = ExpansionPlan::new(&cfg, vec![op.clone()]).expect("valid op");
            let stats = bench(1, 5, || {
                plan.materialize(&params, &opts, &mut Pcg32::seeded(2)).expect("surgery")
            });
            rep.row(
                &format!("{scale_name:<14} {op_name}"),
                &stats,
                vec![("params", Value::num(n_params as f64)), ("op", Value::str(op.kind()))],
            );
        }
        // full boundary cost including Adam moment surgery
        let tcfg = TrainConfig { optimizer: OptimKind::Adam, ..Default::default() };
        let boundary_ops =
            vec![GrowthOp::Mlp { p: cfg.mlp * 2 }, GrowthOp::HeadsAdd { count: 1 }];
        let boundary_plan = ExpansionPlan::new(&cfg, boundary_ops).unwrap();
        let stats = bench(1, 3, || {
            let mut opt = Optimizer::new(&tcfg, &params);
            let mut p2 = params.clone();
            boundary_plan
                .apply_train(&mut p2, &mut opt, &opts, &mut Pcg32::seeded(3))
                .unwrap();
            (p2, opt)
        });
        rep.row(
            &format!("{scale_name:<14} boundary(params+adam moments)"),
            &stats,
            vec![("params", Value::num(n_params as f64))],
        );
    }
    rep.flush();
    println!("\ntarget (DESIGN.md §Perf): boundary surgery <= 100 ms at ~10M params.");
}
