//! L3 runtime-overhead decomposition (DESIGN.md §Perf target: coordinator
//! overhead < 10% of PJRT execute time at the final stage).
//!
//! Three sections:
//!
//! * `metrics_overhead` — artifact-free: decode throughput of the serve
//!   engine with the obs registry publishing vs disabled. The registry is
//!   on the per-token hot path, so its cost must stay < 5% (DESIGN.md
//!   §14); ci.sh asserts the row exists.
//! * `span_export_overhead` — artifact-free: the same burst with the full
//!   live span-export path on top (ring push per finished request + a
//!   `/spans` tail client streaming over real TCP), relative to the
//!   metrics-on baseline. Target < 5% (DESIGN.md §15); ci.sh asserts the
//!   row exists.
//! * PJRT step decomposition — breaks one training step into its cost
//!   components (marshal / execute / clip+adam / batch) and reports the
//!   overhead fraction, plus one-time costs (HLO parse+compile) and the
//!   pure-Rust reference forward for scale. Needs `make artifacts`;
//!   skipped with a note when the manifest is absent, so the bench stays
//!   runnable offline.
//!
//! Run: `cargo bench --bench runtime_overhead`

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use texpand::bench_util::{bench, Reporter};
use texpand::ckpt::{Chain, RunCheckpoint};
use texpand::config::{OptimKind, TrainConfig};
use texpand::data::{Batch, Batcher, CorpusKind};
use texpand::generate::Sampler;
use texpand::json::Value;
use texpand::metrics::Timer;
use texpand::obs::{http_stream_lines, MetricsRegistry, MetricsServer, SpanRing};
use texpand::optim::{clip_global_norm, Optimizer};
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::runtime::{tensor_to_literal, tokens_to_literal, Manifest, Runtime};
use texpand::serve::{Engine, EngineOptions};

/// Decode tokens/sec of a fixed serving burst, with the engine publishing
/// into a fresh registry (`metrics` on) or with instrumentation compiled
/// to `None` (`metrics` off). Fresh engine + registry per round so no
/// histogram state carries over; the best of the timed rounds is returned
/// (least scheduler noise), the first round is warmup.
fn decode_tps(metrics: bool) -> f64 {
    let cfg = texpand::config::ModelConfig {
        layers: 2, hidden: 32, heads: 2, k: 16, v: 16, mlp: 64, seq: 48, vocab: 128,
    };
    let mut best = 0.0f64;
    for round in 0..4u64 {
        let registry = MetricsRegistry::new();
        let params = ParamStore::init(&cfg, &mut Pcg32::seeded(7), 0.02);
        let opts = EngineOptions { max_slots: 4, parallel: false, metrics, ..Default::default() };
        let mut engine = Engine::with_registry(params, opts, &registry);
        let sampler = Sampler { seed: round, ..Default::default() };
        for i in 0..8usize {
            let prompt: Vec<u32> =
                (0..8usize).map(|t| ((i * 13 + t * 7) % cfg.vocab) as u32).collect();
            engine.submit(prompt, 24, sampler).unwrap();
        }
        engine.run_until_idle().unwrap();
        let tps = engine.counters().tokens_per_sec();
        if round > 0 {
            best = best.max(tps);
        }
    }
    best
}

/// Decode tokens/sec of the same burst with the full span-export path on:
/// registry publishing, every finished request span pushed into the live
/// ring, and a `/spans` tail client streaming the ring over real TCP for
/// the whole burst. Returns the best timed-round throughput plus the
/// total spans the tail clients received (proof the path was exercised).
fn decode_tps_span_export() -> (f64, usize) {
    let cfg = texpand::config::ModelConfig {
        layers: 2, hidden: 32, heads: 2, k: 16, v: 16, mlp: 64, seq: 48, vocab: 128,
    };
    let mut best = 0.0f64;
    let mut streamed = 0usize;
    for round in 0..4u64 {
        let registry = Arc::new(MetricsRegistry::new());
        let ring = Arc::new(SpanRing::new(1024));
        let srv =
            MetricsServer::bind_with_spans("127.0.0.1:0", registry.clone(), Some(ring.clone()))
                .unwrap();
        let addr = srv.local_addr().to_string();
        let received = Arc::new(AtomicUsize::new(0));
        let tail = {
            let received = received.clone();
            std::thread::spawn(move || {
                let _ = http_stream_lines(
                    &addr,
                    "/spans",
                    std::time::Duration::from_secs(2),
                    None,
                    &mut |_| {
                        received.fetch_add(1, Ordering::Relaxed);
                    },
                );
            })
        };
        // handshake: a warmup line must round-trip before the burst so
        // the tail client is attached while the engine is being timed
        ring.push("{\"event\":\"warmup\"}".to_string());
        let deadline = Timer::start();
        while received.load(Ordering::Relaxed) == 0 && deadline.ms() < 2000.0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let params = ParamStore::init(&cfg, &mut Pcg32::seeded(7), 0.02);
        let opts =
            EngineOptions { max_slots: 4, parallel: false, metrics: true, ..Default::default() };
        let mut engine = Engine::with_registry(params, opts, &registry);
        engine.set_span_ring(ring.clone());
        let sampler = Sampler { seed: round, ..Default::default() };
        for i in 0..8usize {
            let prompt: Vec<u32> =
                (0..8usize).map(|t| ((i * 13 + t * 7) % cfg.vocab) as u32).collect();
            engine.submit(prompt, 24, sampler).unwrap();
        }
        engine.run_until_idle().unwrap();
        let tps = engine.counters().tokens_per_sec();
        srv.shutdown();
        tail.join().unwrap();
        streamed += received.load(Ordering::Relaxed).saturating_sub(1); // minus warmup
        if round > 0 {
            best = best.max(tps);
        }
    }
    (best, streamed)
}

fn main() {
    let mut rep = Reporter::new("runtime_overhead");

    // --- metrics overhead (artifact-free) --------------------------------
    let on_tps = decode_tps(true);
    let off_tps = decode_tps(false);
    let overhead = if off_tps > 0.0 { (off_tps - on_tps) / off_tps } else { 0.0 };
    let kind = vec![("kind", Value::str("metrics_overhead"))];
    rep.value_row("decode tok/s (metrics on)", "tokens_per_sec", on_tps, kind.clone());
    rep.value_row("decode tok/s (metrics off)", "tokens_per_sec", off_tps, kind.clone());
    rep.value_row("metrics overhead (1 - on/off)", "overhead_fraction", overhead, kind);
    println!("target: metrics overhead_fraction < 0.05 (DESIGN.md §14).");

    // --- span-export overhead (artifact-free) ----------------------------
    let (spans_tps, streamed) = decode_tps_span_export();
    let span_overhead = if on_tps > 0.0 { (on_tps - spans_tps) / on_tps } else { 0.0 };
    let kind = vec![("kind", Value::str("span_export_overhead"))];
    rep.value_row("decode tok/s (span export on)", "tokens_per_sec", spans_tps, kind.clone());
    rep.value_row("spans streamed to the tail client", "count", streamed as f64, kind.clone());
    rep.value_row("span export overhead (1 - spans/on)", "overhead_fraction", span_overhead, kind);
    println!("target: span export overhead_fraction < 0.05 (DESIGN.md §15).");

    // --- checkpoint-write overhead (artifact-free) -----------------------
    // cost of one durable recovery point (full RunCheckpoint through
    // Chain::save: serialize + checksum + tmp + fsync + rename) relative
    // to a native training step on the same model, amortized over a
    // --checkpoint-every 10 cadence. Target < 5% (DESIGN.md §16.6).
    {
        let cfg = texpand::config::ModelConfig {
            layers: 2, hidden: 32, heads: 2, k: 16, v: 16, mlp: 64, seq: 32, vocab: 128,
        };
        let mut rng = Pcg32::seeded(21);
        let mut params = ParamStore::init(&cfg, &mut rng, 0.02);
        let tcfg = TrainConfig { optimizer: OptimKind::Adam, ..Default::default() };
        let mut opt = Optimizer::new(&tcfg, &params);
        let batch = Batch::random(&cfg, 4, 2);
        let step = bench(1, 10, || {
            let (loss, grads) =
                texpand::autodiff::loss_and_grads(&cfg, &params, &batch).unwrap();
            opt.step(&mut params, &grads).unwrap();
            loss
        });

        let (adam_t, adam_m, adam_v) = match &opt {
            Optimizer::Adam { t, m, v, .. } => (*t, Some(m.clone()), Some(v.clone())),
            Optimizer::Sgd { .. } => (0, None, None),
        };
        let ck = RunCheckpoint {
            fingerprint: Value::obj(vec![("schedule", Value::str("bench"))]),
            global_step: 10,
            tokens_seen: 10 * 4 * cfg.seq,
            est_flops: 0.0,
            segment: 0,
            local_step: 10,
            surgery_rng: (1, 3, None),
            batcher_rng: (5, 7, None),
            policy: "fixed".into(),
            policy_state: Value::Null,
            opt_kind: "adam".into(),
            adam_t,
            last_plan: None,
            params: params.clone(),
            adam_m,
            adam_v,
        };
        let dir = std::env::temp_dir()
            .join(format!("texpand-bench-ckpt-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let chain = Chain::open(&dir, 2).unwrap();
        let ckpt = bench(1, 10, || chain.save(&ck).unwrap());
        std::fs::remove_dir_all(&dir).ok();

        const EVERY: f64 = 10.0;
        let overhead = ckpt.mean_ns / (EVERY * step.mean_ns);
        let kind = vec![("kind", Value::str("checkpoint_write_overhead"))];
        rep.row(
            "checkpoint write (params + adam moments, small model)",
            &ckpt,
            [kind.clone(), vec![("params", Value::num(params.num_scalars() as f64))]].concat(),
        );
        rep.row("native train step (same model)", &step, kind.clone());
        rep.value_row(
            "checkpoint overhead at --checkpoint-every 10",
            "overhead_fraction",
            overhead,
            kind,
        );
        println!("target: checkpoint overhead_fraction < 0.05 at every=10 (DESIGN.md §16.6).");
    }

    // --- PJRT step decomposition (needs `make artifacts`) ----------------
    let manifest = match Manifest::load("artifacts", "manifest.json") {
        Ok(m) => m,
        Err(e) => {
            println!("\nskipping pjrt step decomposition ({e}); run `make artifacts` to enable");
            rep.flush();
            return;
        }
    };

    // one-time costs: parse + compile per stage
    let mut rt = Runtime::cpu().unwrap();
    for stage_meta in &manifest.stages {
        let t = Timer::start();
        let _ = rt.load_stage(&manifest, &stage_meta.name).unwrap();
        rep.value_row(
            &format!("compile {} (fwd+step, cold)", stage_meta.name),
            "ms",
            t.ms(),
            vec![("stage", Value::str(stage_meta.name.clone()))],
        );
    }

    // hot-path decomposition at the largest stage
    let last = manifest.stages.last().unwrap().name.clone();
    let stage = rt.load_stage(&manifest, &last).unwrap();
    let cfg = stage.meta.config;
    let mut rng = Pcg32::seeded(3);
    let mut params = ParamStore::init(&cfg, &mut rng, 0.02);
    let tcfg = TrainConfig { optimizer: OptimKind::Adam, ..Default::default() };
    let mut opt = Optimizer::new(&tcfg, &params);
    let mut batcher =
        Batcher::from_corpus(CorpusKind::MarkovText, 100_000, cfg.vocab, cfg.seq, manifest.batch, 5).unwrap();
    let batch = batcher.next();

    let marshal = bench(2, 20, || {
        let mut lits: Vec<xla::Literal> = params.tensors().iter().map(|t| tensor_to_literal(t).unwrap()).collect();
        lits.push(tokens_to_literal(&batch.tokens).unwrap());
        lits
    });
    rep.row("marshal params+tokens -> literals", &marshal, vec![("params", Value::num(params.num_scalars() as f64))]);

    let exec = bench(2, 10, || rt.step(&stage, &params, &batch).unwrap());
    rep.row("pjrt step execute (incl. grads out)", &exec, vec![]);

    let (_, grads) = rt.step(&stage, &params, &batch).unwrap();
    let optim = bench(2, 20, || {
        let mut g = grads.clone();
        clip_global_norm(&mut g, 1.0);
        opt.step(&mut params, &g).unwrap();
    });
    rep.row("clip + adam update", &optim, vec![]);

    let data = bench(2, 50, || batcher.next());
    rep.row("batch synthesis", &data, vec![]);

    // plan-apply overhead: the full boundary transaction through the
    // ExpansionPlan seam (validation + construction + params surgery +
    // Adam moment surgery). Once per boundary, not per step — reported so
    // the plan seam's cost stays visible next to the per-step numbers.
    let plan_ops = vec![texpand::config::GrowthOp::Mlp { p: cfg.mlp * 2 }];
    let plan_apply = bench(1, 5, || {
        let plan = texpand::expand::ExpansionPlan::new(&cfg, plan_ops.clone()).unwrap();
        let mut grown = params.clone();
        let mut boundary_opt = Optimizer::new(&tcfg, &params);
        plan.apply_train(
            &mut grown,
            &mut boundary_opt,
            &texpand::expand::ExpandOptions::default(),
            &mut Pcg32::seeded(9),
        )
        .unwrap();
        (grown, boundary_opt)
    });
    rep.row(
        "plan_apply (validate + params + adam moments, mlp x2)",
        &plan_apply,
        vec![("params", Value::num(params.num_scalars() as f64))],
    );

    // the rust reference forward, for scale (oracle only, never hot path)
    let fwd_rust = bench(1, 3, || texpand::model::forward(&cfg, &params, &batch.tokens).unwrap());
    rep.row("rust-oracle forward (probe-only path)", &fwd_rust, vec![]);

    let overhead = (marshal.mean_ns + optim.mean_ns + data.mean_ns) / exec.mean_ns;
    rep.value_row("L3 overhead fraction of execute", "fraction", overhead, vec![]);
    rep.flush();
    println!("\ntarget: overhead fraction < 0.10 at the final stage (DESIGN.md §Perf).");
    println!("note: marshal+adam are also *inside* step wall-time during training; the");
    println!("train-loop ms/step in training_throughput reflects the end-to-end cost.");
}
