"""L2 model tests: shapes, causality, faithfulness to the paper's Eqs 1-5."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import ModelConfig, param_specs
from compile.kernels import ref_attention, ref_mlp, ref_rmsnorm
from compile.model import (
    flatten_params,
    forward,
    init_params,
    loss_fn,
    make_fwd,
    make_step,
    unflatten_params,
)

CFG = ModelConfig(layers=2, hidden=16, heads=2, k=8, v=8, mlp=32, seq=16, vocab=32)


def _tokens(key, cfg=CFG, batch=2):
    return jax.random.randint(jax.random.PRNGKey(key), (batch, cfg.seq), 0, cfg.vocab)


class TestForward:
    def test_logits_shape(self):
        p = init_params(CFG, 0)
        out = forward(CFG, p, _tokens(1))
        assert out.shape == (2, CFG.seq, CFG.vocab)
        assert out.dtype == jnp.float32

    def test_causality(self):
        """Changing token t must not change logits at positions < t."""
        p = init_params(CFG, 0)
        tok = _tokens(1)
        t = CFG.seq // 2
        tok2 = tok.at[:, t].set((tok[:, t] + 1) % CFG.vocab)
        a, b = forward(CFG, p, tok), forward(CFG, p, tok2)
        np.testing.assert_allclose(a[:, :t], b[:, :t], atol=1e-6)
        assert not np.allclose(a[:, t:], b[:, t:], atol=1e-4)

    def test_batch_rows_independent(self):
        p = init_params(CFG, 0)
        tok = _tokens(1, batch=3)
        full = forward(CFG, p, tok)
        single = forward(CFG, p, tok[1:2])
        np.testing.assert_allclose(full[1:2], single, atol=1e-5)

    def test_positional_embedding_matters(self):
        """Same token at two positions must produce different logits."""
        p = init_params(CFG, 0)
        tok = jnp.full((1, CFG.seq), 7, jnp.int32)
        out = forward(CFG, p, tok)
        assert not np.allclose(out[0, 0], out[0, 5], atol=1e-4)

    def test_invalid_kernels_flag(self):
        p = init_params(CFG, 0)
        with pytest.raises(ValueError):
            forward(CFG, p, _tokens(1), kernels="cuda")

    def test_single_layer_manual_recomputation(self):
        """Recompute a 1-layer forward from the raw equations (Eqs 1-5)."""
        cfg = ModelConfig(layers=1, hidden=8, heads=2, k=4, v=4, mlp=16, seq=8, vocab=16)
        p = init_params(cfg, 3)
        tok = _tokens(2, cfg, batch=1)
        x = p["embed"][tok] + p["pos"][None]
        nrm = ref_rmsnorm(x, p["layer_0.g_mha"])
        heads = []
        for e in range(cfg.heads):
            q = nrm @ p[f"layer_0.head_{e}.wq"]
            k = nrm @ p[f"layer_0.head_{e}.wk"]
            v = nrm @ p[f"layer_0.head_{e}.wv"]
            heads.append(ref_attention(q, k, v))
        x = x + jnp.concatenate(heads, axis=-1) @ p["layer_0.wo"]
        nrm2 = ref_rmsnorm(x, p["layer_0.g_mlp"])
        x = x + ref_mlp(nrm2, p["layer_0.w1"], p["layer_0.b1"], p["layer_0.w2"], p["layer_0.b2"])
        want = x @ p["w_out"]
        got = forward(cfg, p, tok)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


class TestFlatten:
    def test_roundtrip(self):
        p = init_params(CFG, 0)
        flat = flatten_params(CFG, p)
        back = unflatten_params(CFG, flat)
        assert set(back) == set(p)
        for k in p:
            np.testing.assert_array_equal(p[k], back[k])

    def test_flat_order_matches_specs(self):
        p = init_params(CFG, 0)
        flat = flatten_params(CFG, p)
        for arr, (_, shape) in zip(flat, param_specs(CFG)):
            assert tuple(arr.shape) == shape

    def test_wrong_shape_rejected(self):
        p = init_params(CFG, 0)
        p["w_out"] = jnp.zeros((3, 3))
        with pytest.raises(ValueError):
            flatten_params(CFG, p)

    def test_wrong_count_rejected(self):
        p = init_params(CFG, 0)
        with pytest.raises(ValueError):
            unflatten_params(CFG, flatten_params(CFG, p)[:-1])


class TestLossAndStep:
    def test_loss_is_finite_scalar(self):
        p = init_params(CFG, 0)
        loss = loss_fn(CFG, p, _tokens(1), _tokens(2))
        assert loss.shape == ()
        assert np.isfinite(float(loss))

    def test_loss_near_log_vocab_at_init(self):
        """Random init => roughly uniform predictions => loss ~= ln(vocab)."""
        p = init_params(CFG, 0, scale=0.005)
        loss = float(loss_fn(CFG, p, _tokens(1, batch=4), _tokens(2, batch=4)))
        assert abs(loss - np.log(CFG.vocab)) < 0.5

    def test_perfect_prediction_low_loss(self):
        """A model whose w_out strongly predicts the target must beat init."""
        cfg = ModelConfig(layers=1, hidden=8, heads=1, k=4, v=4, mlp=8, seq=8, vocab=8)
        p = init_params(cfg, 0)
        tok = _tokens(1, cfg, batch=2)
        base = float(loss_fn(cfg, p, tok, tok))
        # teach the model the identity map: embed e_t -> logits peak at t
        p2 = dict(p)
        p2["embed"] = 5.0 * jnp.eye(cfg.vocab, cfg.hidden)
        p2["w_out"] = 5.0 * jnp.eye(cfg.hidden, cfg.vocab)
        taught = float(loss_fn(cfg, p2, tok, tok))
        assert taught < base

    def test_step_returns_loss_and_grads(self):
        p = init_params(CFG, 0)
        flat = flatten_params(CFG, p)
        step = make_step(CFG)
        out = step(*flat, _tokens(1), _tokens(2))
        assert len(out) == 1 + len(flat)
        for g, a in zip(out[1:], flat):
            assert g.shape == a.shape
        assert np.isfinite(float(out[0]))

    def test_grads_nonzero_and_descend(self):
        """One SGD step along the returned grads must reduce the loss."""
        p = init_params(CFG, 0)
        flat = flatten_params(CFG, p)
        tok, tgt = _tokens(1), _tokens(2)
        step = make_step(CFG)
        out = step(*flat, tok, tgt)
        loss0, grads = float(out[0]), out[1:]
        assert any(float(jnp.max(jnp.abs(g))) > 0 for g in grads)
        flat2 = [a - 0.5 * g for a, g in zip(flat, grads)]
        loss1 = float(step(*flat2, tok, tgt)[0])
        assert loss1 < loss0

    def test_fwd_entrypoint_matches_forward(self):
        p = init_params(CFG, 0)
        flat = flatten_params(CFG, p)
        tok = _tokens(1)
        (logits,) = make_fwd(CFG)(*flat, tok)
        np.testing.assert_allclose(logits, forward(CFG, p, tok), atol=1e-6)


class TestPallasVariant:
    def test_pallas_model_matches_jnp_model(self):
        cfg = ModelConfig(layers=1, hidden=16, heads=2, k=8, v=8, mlp=32, seq=16, vocab=32)
        p = init_params(cfg, 1)
        tok = _tokens(5, cfg)
        a = forward(cfg, p, tok, kernels="jnp")
        b = forward(cfg, p, tok, kernels="pallas")
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-5)
