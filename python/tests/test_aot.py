"""AOT pipeline tests: lowering, manifest schema, HLO-text invariants."""

import json
import os

import pytest

from compile.aot import build_manifest, lower_stage, main as aot_main
from compile.configs import GrowthSchedule, ModelConfig, param_specs

TINY = {
    "name": "tiny",
    "batch": 2,
    "seq": 8,
    "vocab": 16,
    "base": {"layers": 1, "hidden": 8, "heads": 1, "k": 4, "v": 4, "mlp": 8},
    "stages": [{"steps": 5}, {"steps": 5, "apply": [{"op": "mlp", "p": 16}]}],
}


@pytest.fixture(scope="module")
def tiny_sched():
    return GrowthSchedule.from_dict(TINY)


@pytest.fixture(scope="module")
def tiny_hlo(tiny_sched):
    cfg = tiny_sched.stages[0].config
    return lower_stage(cfg, tiny_sched.batch, "jnp")


class TestLowering:
    def test_hlo_text_has_entry(self, tiny_hlo):
        fwd, step = tiny_hlo
        assert "ENTRY" in fwd and "ENTRY" in step
        assert "HloModule" in fwd

    @staticmethod
    def _entry_param_count(hlo: str) -> int:
        # nested computations also declare parameters; count ENTRY's only
        entry = hlo[hlo.index("ENTRY") :]
        return entry.count(" parameter(")

    def test_fwd_parameter_count(self, tiny_sched, tiny_hlo):
        """fwd takes |params| + 1 (tokens) positional inputs."""
        fwd, _ = tiny_hlo
        cfg = tiny_sched.stages[0].config
        assert self._entry_param_count(fwd) == len(param_specs(cfg)) + 1

    def test_step_parameter_count(self, tiny_sched, tiny_hlo):
        _, step = tiny_hlo
        cfg = tiny_sched.stages[0].config
        assert self._entry_param_count(step) == len(param_specs(cfg)) + 2

    def test_fwd_output_shape_in_text(self, tiny_sched, tiny_hlo):
        fwd, _ = tiny_hlo
        cfg = tiny_sched.stages[0].config
        assert f"f32[{tiny_sched.batch},{cfg.seq},{cfg.vocab}]" in fwd

    def test_pallas_variant_lowers(self, tiny_sched):
        cfg = tiny_sched.stages[0].config
        fwd, step = lower_stage(cfg, tiny_sched.batch, "pallas")
        assert "ENTRY" in fwd and "ENTRY" in step
        # interpret-mode pallas must not leave Mosaic custom-calls behind
        assert "tpu_custom_call" not in fwd and "mosaic" not in fwd.lower()


class TestManifest:
    def test_schema(self, tiny_sched):
        m = build_manifest(tiny_sched, "jnp")
        assert m["version"] == 1
        assert m["batch"] == 2
        assert len(m["stages"]) == 2
        s0 = m["stages"][0]
        assert s0["name"] == "stage0"
        assert s0["fwd"] == "stage0.fwd.hlo.txt"
        assert [p["name"] for p in s0["params"]][0] == "embed"
        assert s0["num_params"] == tiny_sched.stages[0].config.num_params()

    def test_pallas_suffix(self, tiny_sched):
        m = build_manifest(tiny_sched, "pallas")
        assert m["stages"][0]["fwd"] == "stage0.pallas.fwd.hlo.txt"

    def test_param_shapes_match_config(self, tiny_sched):
        m = build_manifest(tiny_sched, "jnp")
        for stage, st_meta in zip(tiny_sched.stages, m["stages"]):
            want = [(n, list(s)) for n, s in param_specs(stage.config)]
            got = [(p["name"], p["shape"]) for p in st_meta["params"]]
            assert got == want


class TestEndToEndAot:
    def test_main_writes_artifacts(self, tmp_path):
        sched_path = tmp_path / "sched.json"
        sched_path.write_text(json.dumps(TINY))
        out = tmp_path / "artifacts"
        rc = aot_main(["--schedule", str(sched_path), "--out-dir", str(out)])
        assert rc == 0
        manifest = json.loads((out / "manifest.json").read_text())
        for st_meta in manifest["stages"]:
            for kind in ("fwd", "step"):
                text = (out / st_meta[kind]).read_text()
                assert "ENTRY" in text

    def test_identical_configs_share_artifacts(self, tmp_path):
        d = dict(TINY)
        d["stages"] = [{"steps": 5}, {"steps": 7}]  # same config twice
        sched_path = tmp_path / "sched.json"
        sched_path.write_text(json.dumps(d))
        out = tmp_path / "artifacts"
        aot_main(["--schedule", str(sched_path), "--out-dir", str(out)])
        manifest = json.loads((out / "manifest.json").read_text())
        assert manifest["stages"][0]["fwd"] == manifest["stages"][1]["fwd"]
        # only one pair of HLO files on disk
        hlo_files = [f for f in os.listdir(out) if f.endswith(".hlo.txt")]
        assert len(hlo_files) == 2
