"""Theorem-level verification of the six function-preserving expansions.

For every transformation (Thms 3.1-3.6) we test:
  * positive: zero-init constraints => logits preserved to float tolerance;
  * freedom:  the matrices the theorems leave unconstrained can be randomized
    aggressively and preservation still holds;
  * negative: violating the constraint (zero_constrained=False) breaks
    preservation — i.e. the constraint set is not vacuous;
plus the two scaling factors (Eqs. 19, 24) the paper singles out, and
composability over random op sequences (hypothesis).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import transforms as T
from compile.configs import ModelConfig, param_specs
from compile.model import forward, init_params

CFG = ModelConfig(layers=2, hidden=16, heads=2, k=8, v=8, mlp=32, seq=16, vocab=32)
PRESERVE_TOL = 1e-4  # DESIGN.md §8
BREAK_TOL = 1e-2

# scale-up initializer: exercises the full freedom the theorems claim
def big_init(key, shape):
    return 0.5 * jax.random.normal(key, shape, jnp.float32)


def _setup(seed=0, cfg=CFG, batch=2, scale=0.02):
    """scale=0.02 is a realistic init; the negative controls for the
    *scaling factors* use a larger scale so attention scores are O(1) —
    at tiny scale the softmax is near-uniform and insensitive to the
    missing sqrt factor, which would make the negative test vacuous."""
    params = init_params(cfg, seed, scale=scale)
    tok = jax.random.randint(jax.random.PRNGKey(seed + 100), (batch, cfg.seq), 0, cfg.vocab)
    return params, tok, forward(cfg, params, tok)


def _delta(cfg2, params2, tok, base):
    return float(jnp.max(jnp.abs(forward(cfg2, params2, tok) - base)))


def _check_shapes(cfg2, params2):
    for name, shape in param_specs(cfg2):
        assert tuple(params2[name].shape) == shape, name
    assert len(params2) == len(param_specs(cfg2))


class TestTheorem31MlpExpansion:
    def test_preserved(self):
        params, tok, base = _setup()
        cfg2, p2 = T.expand_mlp(CFG, params, 64, key=jax.random.PRNGKey(1))
        _check_shapes(cfg2, p2)
        assert _delta(cfg2, p2, tok, base) <= PRESERVE_TOL

    def test_freedom_of_unconstrained(self):
        params, tok, base = _setup()
        cfg2, p2 = T.expand_mlp(CFG, params, 64, key=jax.random.PRNGKey(2), init_fn=big_init)
        assert _delta(cfg2, p2, tok, base) <= PRESERVE_TOL

    def test_violating_constraint_breaks(self):
        params, tok, base = _setup()
        cfg2, p2 = T.expand_mlp(CFG, params, 64, key=jax.random.PRNGKey(3), zero_constrained=False, init_fn=big_init)
        assert _delta(cfg2, p2, tok, base) > BREAK_TOL

    def test_old_slices_untouched(self):
        params, _, _ = _setup()
        cfg2, p2 = T.expand_mlp(CFG, params, 64)
        for n in range(CFG.layers):
            np.testing.assert_array_equal(p2[f"layer_{n}.w1"][:, : CFG.mlp], params[f"layer_{n}.w1"])
            np.testing.assert_array_equal(p2[f"layer_{n}.w2"][: CFG.mlp, :], params[f"layer_{n}.w2"])
            np.testing.assert_array_equal(p2[f"layer_{n}.b1"][: CFG.mlp], params[f"layer_{n}.b1"])

    def test_non_growth_rejected(self):
        params, _, _ = _setup()
        with pytest.raises(ValueError):
            T.expand_mlp(CFG, params, CFG.mlp)


class TestTheorem32HeadAddition:
    def test_preserved_one_head(self):
        params, tok, base = _setup()
        cfg2, p2 = T.add_heads(CFG, params, 1, key=jax.random.PRNGKey(1), init_fn=big_init)
        _check_shapes(cfg2, p2)
        assert cfg2.heads == CFG.heads + 1
        assert _delta(cfg2, p2, tok, base) <= PRESERVE_TOL

    def test_preserved_multiple_heads(self):
        params, tok, base = _setup()
        cfg2, p2 = T.add_heads(CFG, params, 3, key=jax.random.PRNGKey(2))
        assert cfg2.heads == CFG.heads + 3
        assert _delta(cfg2, p2, tok, base) <= PRESERVE_TOL

    def test_violating_constraint_breaks(self):
        params, tok, base = _setup()
        cfg2, p2 = T.add_heads(CFG, params, 1, key=jax.random.PRNGKey(3), zero_constrained=False, init_fn=big_init)
        assert _delta(cfg2, p2, tok, base) > BREAK_TOL

    def test_wo_block_structure(self):
        """New W^O rows sit *below* the old block (Eq. 11)."""
        params, _, _ = _setup()
        _, p2 = T.add_heads(CFG, params, 1)
        old_rows = CFG.heads * CFG.v
        np.testing.assert_array_equal(p2["layer_0.wo"][:old_rows], params["layer_0.wo"])
        np.testing.assert_array_equal(p2["layer_0.wo"][old_rows:], 0.0)


class TestTheorem33HeadsExpansion:
    def test_preserved(self):
        params, tok, base = _setup()
        cfg2, p2 = T.expand_heads(CFG, params, 16, key=jax.random.PRNGKey(1), init_fn=big_init)
        _check_shapes(cfg2, p2)
        assert _delta(cfg2, p2, tok, base) <= PRESERVE_TOL

    def test_violating_constraint_breaks(self):
        params, tok, base = _setup()
        cfg2, p2 = T.expand_heads(CFG, params, 16, key=jax.random.PRNGKey(2), zero_constrained=False, init_fn=big_init)
        assert _delta(cfg2, p2, tok, base) > BREAK_TOL

    def test_wo_interleaved_split_structure(self):
        """W^O expansion is *per-split* row insertion (Eq. 14/15), not an
        append at the bottom."""
        params, _, _ = _setup()
        new_v = 16
        _, p2 = T.expand_heads(CFG, params, new_v)
        wo, wo2 = params["layer_0.wo"], p2["layer_0.wo"]
        for e in range(CFG.heads):
            np.testing.assert_array_equal(wo2[e * new_v : e * new_v + CFG.v], wo[e * CFG.v : (e + 1) * CFG.v])
            np.testing.assert_array_equal(wo2[e * new_v + CFG.v : (e + 1) * new_v], 0.0)


class TestTheorem34AttentionExpansion:
    def test_preserved(self):
        params, tok, base = _setup()
        cfg2, p2 = T.expand_attention(CFG, params, 16, key=jax.random.PRNGKey(1), init_fn=big_init)
        _check_shapes(cfg2, p2)
        assert _delta(cfg2, p2, tok, base) <= PRESERVE_TOL

    def test_violating_zero_constraint_breaks(self):
        params, tok, base = _setup(scale=0.3)
        cfg2, p2 = T.expand_attention(CFG, params, 16, key=jax.random.PRNGKey(2), zero_constrained=False, init_fn=big_init)
        assert _delta(cfg2, p2, tok, base) > BREAK_TOL

    def test_key_scaling_factor_applied(self):
        params, _, _ = _setup()
        new_k = 32
        _, p2 = T.expand_attention(CFG, params, new_k)
        factor = np.sqrt(new_k / CFG.k)
        np.testing.assert_allclose(
            p2["layer_0.head_0.wk"][:, : CFG.k], factor * params["layer_0.head_0.wk"], rtol=1e-6
        )
        # queries are NOT scaled (only Eq. 19 touches W^K)
        np.testing.assert_array_equal(p2["layer_0.head_0.wq"][:, : CFG.k], params["layer_0.head_0.wq"])


class TestTheorem35HiddenExpansion:
    def test_preserved(self):
        params, tok, base = _setup()
        cfg2, p2 = T.expand_hidden(CFG, params, 24, key=jax.random.PRNGKey(1), init_fn=big_init)
        _check_shapes(cfg2, p2)
        assert _delta(cfg2, p2, tok, base) <= PRESERVE_TOL

    def test_violating_constraint_breaks(self):
        params, tok, base = _setup()
        cfg2, p2 = T.expand_hidden(CFG, params, 24, key=jax.random.PRNGKey(2), zero_constrained=False, init_fn=big_init)
        assert _delta(cfg2, p2, tok, base) > BREAK_TOL

    def test_norm_gain_scaling(self):
        params, _, _ = _setup()
        new_h = 32
        _, p2 = T.expand_hidden(CFG, params, new_h)
        factor = np.sqrt(CFG.hidden / new_h)
        np.testing.assert_allclose(p2["layer_0.g_mha"][: CFG.hidden], factor * params["layer_0.g_mha"], rtol=1e-6)

    def test_embed_extension_is_zero(self):
        """Eq. 37: M^I := 0 — new embedding columns must be zero for
        exactness (the paper's Eq. 32 'random columns' remark describes the
        non-preserving general case)."""
        params, _, _ = _setup()
        _, p2 = T.expand_hidden(CFG, params, 24)
        np.testing.assert_array_equal(p2["embed"][:, CFG.hidden :], 0.0)
        np.testing.assert_array_equal(p2["pos"][:, CFG.hidden :], 0.0)


class TestTheorem36LayerAddition:
    @pytest.mark.parametrize("position", ["top", "bottom", 1])
    def test_preserved_any_position(self, position):
        params, tok, base = _setup()
        cfg2, p2 = T.add_layers(CFG, params, 1, position, key=jax.random.PRNGKey(1), init_fn=big_init)
        _check_shapes(cfg2, p2)
        assert cfg2.layers == CFG.layers + 1
        assert _delta(cfg2, p2, tok, base) <= PRESERVE_TOL

    def test_preserved_multiple_layers(self):
        params, tok, base = _setup()
        cfg2, p2 = T.add_layers(CFG, params, 3, "bottom", key=jax.random.PRNGKey(2))
        assert cfg2.layers == CFG.layers + 3
        assert _delta(cfg2, p2, tok, base) <= PRESERVE_TOL

    def test_violating_constraint_breaks(self):
        params, tok, base = _setup()
        cfg2, p2 = T.add_layers(CFG, params, 1, "top", key=jax.random.PRNGKey(3), zero_constrained=False, init_fn=big_init)
        assert _delta(cfg2, p2, tok, base) > BREAK_TOL

    def test_downstream_layers_shift(self):
        params, _, _ = _setup()
        _, p2 = T.add_layers(CFG, params, 1, "bottom")
        np.testing.assert_array_equal(p2["layer_1.w1"], params["layer_0.w1"])
        np.testing.assert_array_equal(p2["layer_2.w1"], params["layer_1.w1"])

    def test_invalid_position_rejected(self):
        params, _, _ = _setup()
        with pytest.raises(ValueError):
            T.add_layers(CFG, params, 1, CFG.layers + 1)


class TestScalingFactors:
    """E7: the two factors the paper claims as novel vs prior work."""

    def test_attention_without_key_scaling_breaks(self):
        params, tok, base = _setup(scale=0.3)
        cfg2, p2 = T.expand_attention(CFG, params, 32, key=jax.random.PRNGKey(1), scale_keys=False)
        assert _delta(cfg2, p2, tok, base) > BREAK_TOL

    def test_attention_with_key_scaling_exact(self):
        params, tok, base = _setup()
        cfg2, p2 = T.expand_attention(CFG, params, 32, key=jax.random.PRNGKey(1), scale_keys=True)
        assert _delta(cfg2, p2, tok, base) <= PRESERVE_TOL

    def test_hidden_without_norm_scaling_breaks(self):
        params, tok, base = _setup(scale=0.3)
        cfg2, p2 = T.expand_hidden(CFG, params, 32, key=jax.random.PRNGKey(1), scale_norm=False)
        assert _delta(cfg2, p2, tok, base) > BREAK_TOL

    def test_hidden_with_norm_scaling_exact(self):
        params, tok, base = _setup()
        cfg2, p2 = T.expand_hidden(CFG, params, 32, key=jax.random.PRNGKey(1), scale_norm=True)
        assert _delta(cfg2, p2, tok, base) <= PRESERVE_TOL

    def test_scaling_magnitude_is_sqrt_ratio(self):
        """The error without scaling grows with the expansion ratio — the
        signature of the missing sqrt factor (not some other bug)."""
        params, tok, base = _setup(scale=0.3)
        errs = []
        for new_k in (16, 64):
            cfg2, p2 = T.expand_attention(CFG, params, new_k, key=jax.random.PRNGKey(1), scale_keys=False)
            errs.append(_delta(cfg2, p2, tok, base))
        assert errs[1] > errs[0]


_OP_STRATEGY = st.lists(
    st.sampled_from(
        [
            {"op": "mlp", "add": 16},
            {"op": "heads_add", "count": 1},
            {"op": "heads_expand", "add": 8},
            {"op": "attn_expand", "add": 8},
            {"op": "hidden", "add": 8},
            {"op": "layers_add", "count": 1},
        ]
    ),
    min_size=1,
    max_size=4,
)


def _materialize(cfg, ops):
    """Convert relative 'add' ops to the absolute schedule vocabulary."""
    out = []
    for op in ops:
        if op["op"] == "mlp":
            cfg = dataclasses.replace(cfg, mlp=cfg.mlp + op["add"])
            out.append({"op": "mlp", "p": cfg.mlp})
        elif op["op"] == "heads_add":
            cfg = dataclasses.replace(cfg, heads=cfg.heads + 1)
            out.append(op)
        elif op["op"] == "heads_expand":
            cfg = dataclasses.replace(cfg, v=cfg.v + op["add"])
            out.append({"op": "heads_expand", "v": cfg.v})
        elif op["op"] == "attn_expand":
            cfg = dataclasses.replace(cfg, k=cfg.k + op["add"])
            out.append({"op": "attn_expand", "k": cfg.k})
        elif op["op"] == "hidden":
            cfg = dataclasses.replace(cfg, hidden=cfg.hidden + op["add"])
            out.append({"op": "hidden", "h": cfg.hidden})
        else:
            out.append(op)
    return out


class TestComposability:
    @settings(max_examples=10, deadline=None)
    @given(ops=_OP_STRATEGY, seed=st.integers(0, 1000))
    def test_random_sequences_preserve(self, ops, seed):
        cfg = ModelConfig(layers=1, hidden=8, heads=1, k=4, v=4, mlp=8, seq=8, vocab=16)
        params = init_params(cfg, seed % 7)
        tok = jax.random.randint(jax.random.PRNGKey(seed), (1, cfg.seq), 0, cfg.vocab)
        base = forward(cfg, params, tok)
        cfg2, p2 = T.apply_ops(cfg, params, _materialize(cfg, ops), key=jax.random.PRNGKey(seed + 1))
        _check_shapes(cfg2, p2)
        assert _delta(cfg2, p2, tok, base) <= PRESERVE_TOL

    def test_all_six_composed(self):
        params, tok, base = _setup()
        ops = [
            {"op": "mlp", "p": 64},
            {"op": "heads_add", "count": 1},
            {"op": "heads_expand", "v": 16},
            {"op": "attn_expand", "k": 16},
            {"op": "hidden", "h": 32},
            {"op": "layers_add", "count": 2, "position": "top"},
        ]
        cfg2, p2 = T.apply_ops(CFG, params, ops, key=jax.random.PRNGKey(5))
        _check_shapes(cfg2, p2)
        assert _delta(cfg2, p2, tok, base) <= PRESERVE_TOL

    def test_default_schedule_ops_preserve(self):
        """The shipped growth schedule's boundary ops, end to end."""
        import json

        from tests.conftest import GROWTH_DEFAULT
        with open(GROWTH_DEFAULT) as f:
            sched = json.load(f)
        cfg = ModelConfig.from_dict({**sched["base"], "seq": 16, "vocab": 64})
        params = init_params(cfg, 11)
        tok = jax.random.randint(jax.random.PRNGKey(0), (2, cfg.seq), 0, cfg.vocab)
        base = forward(cfg, params, tok)
        for stage in sched["stages"][1:]:
            cfg, params = T.apply_ops(cfg, params, stage["apply"], key=jax.random.PRNGKey(1))
            assert _delta(cfg, params, tok, base) <= PRESERVE_TOL
