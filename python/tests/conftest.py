import os
import sys

# Allow `from compile import ...` regardless of pytest invocation directory.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# Absolute path to the shipped growth schedule (tests must be cwd-independent).
GROWTH_DEFAULT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "configs", "growth_default.json")
)
