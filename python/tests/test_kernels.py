"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes and block sizes; explicit cases pin the edge
conditions (single block, uneven head widths dk != dv, non-causal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    pallas_attention,
    pallas_mlp,
    pallas_rmsnorm,
    ref_attention,
    ref_mlp,
    ref_rmsnorm,
)
from compile.kernels.attention import vmem_footprint_bytes as attn_vmem
from compile.kernels.mlp import vmem_footprint_bytes as mlp_vmem

ATOL = 2e-5
RTOL = 2e-5


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestAttentionKernel:
    @settings(max_examples=12, deadline=None)
    @given(
        bh=st.integers(1, 4),
        seq_blocks=st.integers(1, 4),
        block=st.sampled_from([8, 16, 32]),
        dk=st.sampled_from([4, 8, 16]),
        dv=st.sampled_from([4, 8, 24]),
        causal=st.booleans(),
    )
    def test_matches_ref_swept(self, bh, seq_blocks, block, dk, dv, causal):
        seq = seq_blocks * block
        q = _rand(1, (bh, seq, dk))
        k = _rand(2, (bh, seq, dk))
        v = _rand(3, (bh, seq, dv))
        got = pallas_attention(q, k, v, causal=causal, block_q=block, block_kv=block)
        want = ref_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)

    def test_single_block_degenerate(self):
        q, k, v = _rand(1, (1, 8, 4)), _rand(2, (1, 8, 4)), _rand(3, (1, 8, 4))
        got = pallas_attention(q, k, v, block_q=8, block_kv=8)
        np.testing.assert_allclose(got, ref_attention(q, k, v), atol=ATOL, rtol=RTOL)

    def test_blocks_clamp_to_seq(self):
        # default blocks (128) exceed seq=16: must clamp, not raise
        q, k, v = _rand(1, (2, 16, 8)), _rand(2, (2, 16, 8)), _rand(3, (2, 16, 8))
        got = pallas_attention(q, k, v)
        np.testing.assert_allclose(got, ref_attention(q, k, v), atol=ATOL, rtol=RTOL)

    def test_indivisible_seq_raises(self):
        q, k, v = _rand(1, (1, 24, 4)), _rand(2, (1, 24, 4)), _rand(3, (1, 24, 4))
        with pytest.raises(ValueError):
            pallas_attention(q, k, v, block_q=16, block_kv=16)

    def test_causality_no_future_leak(self):
        """Perturbing position j must not change outputs at positions < j."""
        q, k, v = _rand(1, (1, 32, 8)), _rand(2, (1, 32, 8)), _rand(3, (1, 32, 8))
        base = pallas_attention(q, k, v, block_q=8, block_kv=8)
        k2 = k.at[:, 20, :].add(100.0)
        v2 = v.at[:, 20, :].add(100.0)
        pert = pallas_attention(q, k2, v2, block_q=8, block_kv=8)
        np.testing.assert_allclose(pert[:, :20], base[:, :20], atol=1e-6)
        assert not np.allclose(pert[:, 20:], base[:, 20:], atol=1e-3)

    def test_large_score_stability(self):
        """Online softmax must survive large logits without overflow."""
        q = _rand(1, (1, 16, 8), scale=30.0)
        k = _rand(2, (1, 16, 8), scale=30.0)
        v = _rand(3, (1, 16, 8))
        got = pallas_attention(q, k, v, block_q=8, block_kv=8)
        assert np.isfinite(np.asarray(got)).all()
        np.testing.assert_allclose(got, ref_attention(q, k, v), atol=1e-4, rtol=1e-4)

    def test_vmem_estimate_positive_and_monotone(self):
        a = attn_vmem(seq=128, dk=32, dv=32)
        b = attn_vmem(seq=256, dk=32, dv=32)
        assert 0 < a < b


class TestMlpKernel:
    @settings(max_examples=12, deadline=None)
    @given(
        rows_blocks=st.integers(1, 3),
        p_blocks=st.integers(1, 3),
        block=st.sampled_from([8, 16]),
        h=st.sampled_from([4, 16, 24]),
    )
    def test_matches_ref_swept(self, rows_blocks, p_blocks, block, h):
        rows, p = rows_blocks * block, p_blocks * block
        x = _rand(1, (rows, h))
        w1, b1 = _rand(2, (h, p), 0.2), _rand(3, (p,), 0.2)
        w2, b2 = _rand(4, (p, h), 0.2), _rand(5, (h,), 0.2)
        got = pallas_mlp(x, w1, b1, w2, b2, block_rows=block, block_p=block)
        np.testing.assert_allclose(got, ref_mlp(x, w1, b1, w2, b2), atol=ATOL, rtol=RTOL)

    def test_relu_tiling_is_exact_at_boundary(self):
        """ReLU is elementwise over p, so p-tiling must be exact even when
        activations straddle zero at tile boundaries."""
        x = jnp.ones((8, 4))
        w1 = jnp.concatenate([jnp.full((4, 8), -0.25), jnp.full((4, 8), 0.25)], axis=1)
        b1 = jnp.zeros(16)
        w2 = _rand(4, (16, 4), 0.5)
        b2 = jnp.zeros(4)
        got = pallas_mlp(x, w1, b1, w2, b2, block_rows=8, block_p=8)
        np.testing.assert_allclose(got, ref_mlp(x, w1, b1, w2, b2), atol=1e-6)

    def test_indivisible_p_raises(self):
        with pytest.raises(ValueError):
            pallas_mlp(jnp.ones((8, 4)), jnp.ones((4, 24)), jnp.ones(24), jnp.ones((24, 4)), jnp.ones(4), block_rows=8, block_p=16)

    def test_vmem_estimate(self):
        assert mlp_vmem(h=128, p=512) > 0


class TestRmsnormKernel:
    @settings(max_examples=10, deadline=None)
    @given(rows=st.sampled_from([8, 16, 64]), h=st.sampled_from([4, 16, 96]), block=st.sampled_from([8, 16]))
    def test_matches_ref_swept(self, rows, h, block):
        if rows % block:
            rows = block
        x = _rand(1, (rows, h))
        g = _rand(2, (h,))
        got = pallas_rmsnorm(x, g, block_rows=block)
        np.testing.assert_allclose(got, ref_rmsnorm(x, g), atol=ATOL, rtol=RTOL)

    def test_scale_invariance_property(self):
        """RMSNorm(c*x) == RMSNorm(x) for c > 0 — the property Thm 3.5's
        norm-scaling relies on."""
        x, g = _rand(1, (16, 8)), _rand(2, (8,))
        a = pallas_rmsnorm(x, g, block_rows=16)
        b = pallas_rmsnorm(3.5 * x, g, block_rows=16)
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)

    def test_eps_zero_matches_paper_eq5(self):
        x = jnp.array([[3.0, 4.0]])
        g = jnp.array([2.0, 0.5])
        got = pallas_rmsnorm(x, g, block_rows=1)
        rms = np.sqrt((9 + 16) / 2)
        np.testing.assert_allclose(got, [[2 * 3 / rms, 0.5 * 4 / rms]], rtol=1e-6)


class TestRefOracles:
    def test_ref_attention_uniform_when_keys_equal(self):
        """All-equal keys => uniform causal attention => running mean of V."""
        s = 8
        q = _rand(1, (1, s, 4))
        k = jnp.ones((1, s, 4))
        v = jnp.arange(s, dtype=jnp.float32)[None, :, None] * jnp.ones((1, s, 3))
        out = ref_attention(q, k, v)
        want = jnp.cumsum(v[0, :, 0]) / jnp.arange(1, s + 1)
        np.testing.assert_allclose(out[0, :, 0], want, rtol=1e-5)

    def test_ref_mlp_zero_weights_give_bias(self):
        x = _rand(1, (4, 8))
        out = ref_mlp(x, jnp.zeros((8, 16)), jnp.zeros(16), jnp.zeros((16, 8)), jnp.full(8, 1.5))
        np.testing.assert_allclose(out, 1.5 * jnp.ones((4, 8)))


class TestKernelGradients:
    """The Pallas kernels carry custom_vjp rules (backward = vjp of the
    reference — interpret-mode pallas_call cannot be re-traced for AD under
    AOT lowering). These tests pin that the gradients they produce equal
    the pure-jnp gradients, so the `--kernels pallas` step artifacts train
    identically to the jnp ones."""

    def test_attention_grads_match_ref(self):
        q, k, v = _rand(1, (2, 16, 8)), _rand(2, (2, 16, 8)), _rand(3, (2, 16, 8))

        def loss_pallas(q, k, v):
            return jnp.sum(pallas_attention(q, k, v, block_q=8, block_kv=8) ** 2)

        def loss_ref(q, k, v):
            return jnp.sum(ref_attention(q, k, v) ** 2)

        gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b, name in zip(gp, gr, "qkv"):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4, err_msg=name)

    def test_mlp_grads_match_ref(self):
        x = _rand(1, (16, 8))
        w1, b1 = _rand(2, (8, 16), 0.3), _rand(3, (16,), 0.3)
        w2, b2 = _rand(4, (16, 8), 0.3), _rand(5, (8,), 0.3)

        def loss_pallas(*args):
            return jnp.sum(pallas_mlp(*args, block_rows=8, block_p=8) ** 2)

        def loss_ref(*args):
            return jnp.sum(ref_mlp(*args) ** 2)

        gp = jax.grad(loss_pallas, argnums=tuple(range(5)))(x, w1, b1, w2, b2)
        gr = jax.grad(loss_ref, argnums=tuple(range(5)))(x, w1, b1, w2, b2)
        for a, b in zip(gp, gr):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)

    def test_pallas_step_lowering_has_grads(self):
        """The full pallas-variant train step lowers and its grads descend."""
        from compile.configs import ModelConfig
        from compile.model import flatten_params, init_params, make_step

        cfg = ModelConfig(layers=1, hidden=8, heads=1, k=4, v=4, mlp=8, seq=8, vocab=16)
        p = init_params(cfg, 0)
        flat = flatten_params(cfg, p)
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 16)
        tgt = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 16)
        step = make_step(cfg, kernels="pallas")
        out = step(*flat, tok, tgt)
        loss0 = float(out[0])
        flat2 = [a - 0.5 * g for a, g in zip(flat, out[1:])]
        loss1 = float(step(*flat2, tok, tgt)[0])
        assert loss1 < loss0
