"""Tests for the config/schedule contract shared with the Rust coordinator."""

import dataclasses

import pytest

from compile.configs import (
    GrowthSchedule,
    ModelConfig,
    apply_op_to_config,
    param_specs,
)

CFG = ModelConfig(layers=2, hidden=16, heads=2, k=8, v=8, mlp=32, seq=16, vocab=32)


class TestModelConfig:
    def test_validate_accepts_positive(self):
        CFG.validate()

    @pytest.mark.parametrize("field", ["layers", "hidden", "heads", "k", "v", "mlp", "seq", "vocab"])
    def test_validate_rejects_nonpositive(self, field):
        with pytest.raises(ValueError):
            dataclasses.replace(CFG, **{field: 0}).validate()
        with pytest.raises(ValueError):
            dataclasses.replace(CFG, **{field: -3}).validate()

    def test_dict_roundtrip(self):
        assert ModelConfig.from_dict(CFG.to_dict()) == CFG

    def test_from_dict_requires_all_fields(self):
        d = CFG.to_dict()
        del d["heads"]
        with pytest.raises(KeyError):
            ModelConfig.from_dict(d)

    def test_num_params_matches_specs(self):
        total = sum(
            int.__mul__(*shape) if len(shape) == 2 else shape[0] for _, shape in param_specs(CFG)
        )
        assert CFG.num_params() == total

    def test_num_params_grows_with_each_dim(self):
        for field, delta in [
            ("layers", 1),
            ("hidden", 8),
            ("heads", 1),
            ("k", 8),
            ("v", 8),
            ("mlp", 8),
        ]:
            bigger = dataclasses.replace(CFG, **{field: getattr(CFG, field) + delta})
            assert bigger.num_params() > CFG.num_params(), field


class TestParamSpecs:
    def test_canonical_order_prefix(self):
        names = [n for n, _ in param_specs(CFG)]
        assert names[0] == "embed"
        assert names[1] == "pos"
        assert names[2] == "layer_0.g_mha"
        assert names[3] == "layer_0.head_0.wq"
        assert names[-1] == "w_out"

    def test_count_formula(self):
        specs = param_specs(CFG)
        # per layer: g_mha + 3 mats per head + wo + g_mlp + w1 + b1 + w2 + b2
        assert len(specs) == 2 + CFG.layers * (3 * CFG.heads + 7) + 1

    def test_shapes_follow_paper(self):
        d = dict(param_specs(CFG))
        assert d["embed"] == (CFG.vocab, CFG.hidden)
        assert d["pos"] == (CFG.seq, CFG.hidden)
        assert d["layer_0.head_1.wq"] == (CFG.hidden, CFG.k)
        assert d["layer_0.head_1.wv"] == (CFG.hidden, CFG.v)
        assert d["layer_1.wo"] == (CFG.heads * CFG.v, CFG.hidden)
        assert d["layer_1.w1"] == (CFG.hidden, CFG.mlp)
        assert d["layer_1.w2"] == (CFG.mlp, CFG.hidden)
        assert d["w_out"] == (CFG.hidden, CFG.vocab)

    def test_names_unique(self):
        names = [n for n, _ in param_specs(CFG)]
        assert len(names) == len(set(names))


class TestOps:
    def test_each_op_changes_only_its_dim(self):
        cases = {
            "mlp": ({"op": "mlp", "p": 64}, "mlp", 64),
            "heads_add": ({"op": "heads_add", "count": 2}, "heads", 4),
            "heads_expand": ({"op": "heads_expand", "v": 16}, "v", 16),
            "attn_expand": ({"op": "attn_expand", "k": 16}, "k", 16),
            "hidden": ({"op": "hidden", "h": 32}, "hidden", 32),
            "layers_add": ({"op": "layers_add", "count": 1}, "layers", 3),
        }
        for name, (op, field, expect) in cases.items():
            out = apply_op_to_config(CFG, op)
            assert getattr(out, field) == expect, name
            for f in dataclasses.fields(ModelConfig):
                if f.name != field:
                    assert getattr(out, f.name) == getattr(CFG, f.name), (name, f.name)

    @pytest.mark.parametrize(
        "op",
        [
            {"op": "mlp", "p": 32},  # not growing
            {"op": "mlp", "p": 16},
            {"op": "heads_add", "count": 0},
            {"op": "heads_expand", "v": 8},
            {"op": "attn_expand", "k": 4},
            {"op": "hidden", "h": 16},
            {"op": "layers_add", "count": 0},
            {"op": "shrink", "h": 8},  # unknown kind
        ],
    )
    def test_invalid_ops_rejected(self, op):
        with pytest.raises(ValueError):
            apply_op_to_config(CFG, op)


class TestGrowthSchedule:
    def _base(self):
        return {
            "name": "t",
            "batch": 4,
            "seq": 16,
            "vocab": 32,
            "base": {"layers": 1, "hidden": 16, "heads": 2, "k": 8, "v": 8, "mlp": 32},
            "stages": [
                {"steps": 10},
                {"steps": 10, "apply": [{"op": "mlp", "p": 64}]},
            ],
        }

    def test_stage_configs_accumulate(self):
        sched = GrowthSchedule.from_dict(self._base())
        assert sched.stages[0].config.mlp == 32
        assert sched.stages[1].config.mlp == 64
        assert sched.stages[0].name == "stage0"
        assert sched.stages[1].apply == ({"op": "mlp", "p": 64},)

    def test_stage0_cannot_apply(self):
        d = self._base()
        d["stages"][0]["apply"] = [{"op": "mlp", "p": 64}]
        with pytest.raises(ValueError):
            GrowthSchedule.from_dict(d)

    def test_empty_stages_rejected(self):
        d = self._base()
        d["stages"] = []
        with pytest.raises(ValueError):
            GrowthSchedule.from_dict(d)

    def test_non_monotone_dim_rejected(self):
        d = self._base()
        d["stages"].append({"steps": 5, "apply": [{"op": "mlp", "p": 48}]})  # 64 -> 48
        with pytest.raises(ValueError):
            GrowthSchedule.from_dict(d)

    def test_default_schedule_file_loads(self):
        from tests.conftest import GROWTH_DEFAULT
        sched = GrowthSchedule.load(GROWTH_DEFAULT)
        assert len(sched.stages) >= 2
        # every stage strictly grows parameter count
        counts = [st.config.num_params() for st in sched.stages]
        assert counts == sorted(counts) and len(set(counts)) == len(counts)
