"""The paper's six function-preserving expansions — JAX reference impl.

Each transformation mirrors its Definition in Section 3 and enforces the
zero-init constraints of the matching Theorem; all matrices the theorems
leave *unconstrained* are filled by `init_fn` (default: random normal), so
the pytest suite exercises exactly the freedom the proofs claim.

This module is the cross-language oracle for `rust/src/expand/`: both sides
implement the same surgery on the canonical parameter layout
(configs.param_specs), and integration tests compare them via golden
artifacts and via end-to-end logit preservation.

Constraint map (Table 1):
    3.1 MLP expansion        p -> p_hat   zero: new rows of W2
    3.2 Head addition        E -> E+1     zero: new v rows of WO
    3.3 Heads expansion      v -> v_hat   zero: new rows of each WO split
    3.4 Attention expansion  k -> k_hat   zero: new cols of WK; scale old WK
                                          by sqrt(k_hat)/sqrt(k)
    3.5 Hidden expansion     h -> h_hat   zero: new cols of P, W2, b2, WO,
                                          embed (M^I, Eq. 37); scale norm g
                                          by sqrt(h)/sqrt(h_hat)
    3.6 Layer addition       N -> N+1     zero: new layer's WO, W2, b2
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .configs import ModelConfig, apply_op_to_config
from .model import Params

InitFn = Callable[[jax.Array, tuple[int, ...]], jnp.ndarray]


def default_init(key: jax.Array, shape: tuple[int, ...]) -> jnp.ndarray:
    """Default initializer for unconstrained new parameters."""
    return 0.02 * jax.random.normal(key, shape, jnp.float32)


def zeros_init(key: jax.Array, shape: tuple[int, ...]) -> jnp.ndarray:
    return jnp.zeros(shape, jnp.float32)


def _split(key: jax.Array) -> tuple[jax.Array, jax.Array]:
    return jax.random.split(key)


# ---------------------------------------------------------------------------
# 3.1 MLP expansion
# ---------------------------------------------------------------------------


def expand_mlp(
    cfg: ModelConfig,
    params: Params,
    new_p: int,
    *,
    key: jax.Array | None = None,
    init_fn: InitFn = default_init,
    zero_constrained: bool = True,
) -> tuple[ModelConfig, Params]:
    """Def. 3.1: grow the MLP internal dimension p -> new_p in every layer.

    `zero_constrained=False` deliberately violates Thm 3.1 (used by the E6
    ablation to show preservation then fails).
    """
    if new_p <= cfg.mlp:
        raise ValueError(f"new_p must exceed p: {cfg.mlp} -> {new_p}")
    key = jax.random.PRNGKey(0) if key is None else key
    d = new_p - cfg.mlp
    out = dict(params)
    for n in range(cfg.layers):
        key, k1 = _split(key)
        key, k2 = _split(key)
        key, k3 = _split(key)
        m_w1 = init_fn(k1, (cfg.hidden, d))  # unconstrained (Eq. 6)
        m_b1 = init_fn(k2, (d,))  # unconstrained (Eq. 7)
        m_w2 = zeros_init(k3, (d, cfg.hidden)) if zero_constrained else init_fn(k3, (d, cfg.hidden))  # Thm 3.1
        out[f"layer_{n}.w1"] = jnp.concatenate([params[f"layer_{n}.w1"], m_w1], axis=1)
        out[f"layer_{n}.b1"] = jnp.concatenate([params[f"layer_{n}.b1"], m_b1], axis=0)
        out[f"layer_{n}.w2"] = jnp.concatenate([params[f"layer_{n}.w2"], m_w2], axis=0)
    return dataclasses.replace(cfg, mlp=new_p), out


# ---------------------------------------------------------------------------
# 3.2 Head addition
# ---------------------------------------------------------------------------


def add_heads(
    cfg: ModelConfig,
    params: Params,
    count: int = 1,
    *,
    key: jax.Array | None = None,
    init_fn: InitFn = default_init,
    zero_constrained: bool = True,
) -> tuple[ModelConfig, Params]:
    """Def. 3.2: add `count` new attention heads to every layer."""
    if count < 1:
        raise ValueError("count must be >= 1")
    key = jax.random.PRNGKey(0) if key is None else key
    out = dict(params)
    new_e = cfg.heads + count
    for n in range(cfg.layers):
        blocks = [params[f"layer_{n}.wo"]]
        for e in range(cfg.heads, new_e):
            key, kq = _split(key)
            key, kk = _split(key)
            key, kv = _split(key)
            key, ko = _split(key)
            out[f"layer_{n}.head_{e}.wq"] = init_fn(kq, (cfg.hidden, cfg.k))  # unconstrained
            out[f"layer_{n}.head_{e}.wk"] = init_fn(kk, (cfg.hidden, cfg.k))
            out[f"layer_{n}.head_{e}.wv"] = init_fn(kv, (cfg.hidden, cfg.v))
            m_wo = zeros_init(ko, (cfg.v, cfg.hidden)) if zero_constrained else init_fn(ko, (cfg.v, cfg.hidden))
            blocks.append(m_wo)  # Thm 3.2: zero rows appended to W^O
        out[f"layer_{n}.wo"] = jnp.concatenate(blocks, axis=0)
    return dataclasses.replace(cfg, heads=new_e), out


# ---------------------------------------------------------------------------
# 3.3 Heads expansion
# ---------------------------------------------------------------------------


def expand_heads(
    cfg: ModelConfig,
    params: Params,
    new_v: int,
    *,
    key: jax.Array | None = None,
    init_fn: InitFn = default_init,
    zero_constrained: bool = True,
) -> tuple[ModelConfig, Params]:
    """Def. 3.3: grow each head's value/output width v -> new_v.

    W^O is treated as E stacked (v, h) splits (Eq. 15); each split receives
    (new_v - v) *zero* rows (Thm 3.3), interleaved per head.
    """
    if new_v <= cfg.v:
        raise ValueError(f"new_v must exceed v: {cfg.v} -> {new_v}")
    key = jax.random.PRNGKey(0) if key is None else key
    d = new_v - cfg.v
    out = dict(params)
    for n in range(cfg.layers):
        splits = []
        for e in range(cfg.heads):
            key, kv = _split(key)
            key, ko = _split(key)
            m_wv = init_fn(kv, (cfg.hidden, d))  # unconstrained (Eq. 13)
            out[f"layer_{n}.head_{e}.wv"] = jnp.concatenate([params[f"layer_{n}.head_{e}.wv"], m_wv], axis=1)
            split = params[f"layer_{n}.wo"][e * cfg.v : (e + 1) * cfg.v, :]
            m_wo = zeros_init(ko, (d, cfg.hidden)) if zero_constrained else init_fn(ko, (d, cfg.hidden))
            splits.append(jnp.concatenate([split, m_wo], axis=0))
        out[f"layer_{n}.wo"] = jnp.concatenate(splits, axis=0)
    return dataclasses.replace(cfg, v=new_v), out


# ---------------------------------------------------------------------------
# 3.4 Attention expansion
# ---------------------------------------------------------------------------


def expand_attention(
    cfg: ModelConfig,
    params: Params,
    new_k: int,
    *,
    key: jax.Array | None = None,
    init_fn: InitFn = default_init,
    zero_constrained: bool = True,
    scale_keys: bool = True,
) -> tuple[ModelConfig, Params]:
    """Def. 3.4: grow the key/query width k -> new_k.

    The pre-existing key columns are scaled by sqrt(new_k)/sqrt(k) (Eq. 19)
    to compensate the 1/sqrt(k) attention scale; `scale_keys=False` drops
    the factor (E6/E7 ablation — "no known works consider scaling factors").
    """
    if new_k <= cfg.k:
        raise ValueError(f"new_k must exceed k: {cfg.k} -> {new_k}")
    key = jax.random.PRNGKey(0) if key is None else key
    d = new_k - cfg.k
    factor = jnp.sqrt(jnp.float32(new_k)) / jnp.sqrt(jnp.float32(cfg.k)) if scale_keys else jnp.float32(1)
    out = dict(params)
    for n in range(cfg.layers):
        for e in range(cfg.heads):
            key, kq = _split(key)
            key, kk = _split(key)
            m_wq = init_fn(kq, (cfg.hidden, d))  # unconstrained (Eq. 18)
            m_wk = zeros_init(kk, (cfg.hidden, d)) if zero_constrained else init_fn(kk, (cfg.hidden, d))  # Thm 3.4
            out[f"layer_{n}.head_{e}.wq"] = jnp.concatenate([params[f"layer_{n}.head_{e}.wq"], m_wq], axis=1)
            out[f"layer_{n}.head_{e}.wk"] = jnp.concatenate(
                [factor * params[f"layer_{n}.head_{e}.wk"], m_wk], axis=1
            )
    return dataclasses.replace(cfg, k=new_k), out


# ---------------------------------------------------------------------------
# 3.5 Hidden dimension expansion
# ---------------------------------------------------------------------------


def expand_hidden(
    cfg: ModelConfig,
    params: Params,
    new_h: int,
    *,
    key: jax.Array | None = None,
    init_fn: InitFn = default_init,
    zero_constrained: bool = True,
    scale_norm: bool = True,
) -> tuple[ModelConfig, Params]:
    """Def. 3.5: grow the transformer hidden width h -> new_h (all layers).

    Zero-init set (Thm 3.5): new cols of P, W2, b2, W^O, and of the
    embedding table (M^I, Eq. 37). Norm gains are scaled by
    sqrt(h)/sqrt(new_h) (Eq. 24) to compensate RMSNorm's 1/h mean;
    `scale_norm=False` drops it (E6/E7 ablation).
    """
    if new_h <= cfg.hidden:
        raise ValueError(f"new_h must exceed h: {cfg.hidden} -> {new_h}")
    key = jax.random.PRNGKey(0) if key is None else key
    d = new_h - cfg.hidden
    g_factor = jnp.sqrt(jnp.float32(cfg.hidden)) / jnp.sqrt(jnp.float32(new_h)) if scale_norm else jnp.float32(1)
    out = dict(params)

    def grow_cols(name: str, constrained: bool) -> None:
        nonlocal key
        key, k1 = _split(key)
        rows = params[name].shape[0]
        m = zeros_init(k1, (rows, d)) if (constrained and zero_constrained) else init_fn(k1, (rows, d))
        out[name] = jnp.concatenate([params[name], m], axis=1)

    def grow_rows(name: str) -> None:  # always unconstrained in Thm 3.5
        nonlocal key
        key, k1 = _split(key)
        cols = params[name].shape[1]
        out[name] = jnp.concatenate([params[name], init_fn(k1, (d, cols))], axis=0)

    grow_cols("embed", constrained=True)  # M^I := 0 (Eq. 37)
    grow_cols("pos", constrained=True)  # M^P := 0 (Eq. 33)
    grow_rows("w_out")  # M^Wout unconstrained (Eq. 23)
    for n in range(cfg.layers):
        for c in ("g_mha", "g_mlp"):
            key, k1 = _split(key)
            m_g = zeros_init(k1, (d,)) if zero_constrained else init_fn(k1, (d,))
            # NOTE (paper erratum): Thm 3.5's constraint list names the "norm
            # scaling vector" among the zero-inits; Eq. 48's proof only needs
            # the *existing* entries scaled — the new entries multiply zero
            # activations. We zero them anyway (more conservative, and the
            # Rust side must match bit-for-bit).
            out[f"layer_{n}.{c}"] = jnp.concatenate([g_factor * params[f"layer_{n}.{c}"], m_g], axis=0)
        for e in range(cfg.heads):
            grow_rows(f"layer_{n}.head_{e}.wq")
            grow_rows(f"layer_{n}.head_{e}.wk")
            grow_rows(f"layer_{n}.head_{e}.wv")
        grow_cols(f"layer_{n}.wo", constrained=True)  # M^WO := 0 (Eq. 36)
        grow_rows(f"layer_{n}.w1")
        grow_cols(f"layer_{n}.w2", constrained=True)  # M^Wl2 := 0 (Eq. 34)
        key, k1 = _split(key)
        m_b2 = zeros_init(k1, (d,)) if zero_constrained else init_fn(k1, (d,))  # m^bl2 := 0 (Eq. 35)
        out[f"layer_{n}.b2"] = jnp.concatenate([params[f"layer_{n}.b2"], m_b2], axis=0)
    return dataclasses.replace(cfg, hidden=new_h), out


# ---------------------------------------------------------------------------
# 3.6 Layer addition
# ---------------------------------------------------------------------------


def add_layers(
    cfg: ModelConfig,
    params: Params,
    count: int = 1,
    position: int | str = "top",
    *,
    key: jax.Array | None = None,
    init_fn: InitFn = default_init,
    zero_constrained: bool = True,
) -> tuple[ModelConfig, Params]:
    """Def. 3.6: insert `count` identity-initialized layers at `position`.

    position: int in [0, N], or "top" (N) / "bottom" (0). Downstream layer
    indices shift up (Def. 3.6). Thm 3.6 zero-inits the new layers' W^O, W2
    and b2; everything else (norm gains, W^Q/K/V, W1, b1) is unconstrained.
    """
    if count < 1:
        raise ValueError("count must be >= 1")
    pos = {"top": cfg.layers, "bottom": 0}.get(position, position)
    if not isinstance(pos, int) or not 0 <= pos <= cfg.layers:
        raise ValueError(f"position must be in [0, {cfg.layers}] or top/bottom, got {position!r}")
    key = jax.random.PRNGKey(0) if key is None else key
    new_n = cfg.layers + count
    out = {k_: v_ for k_, v_ in params.items() if not k_.startswith("layer_")}

    def old_layer(n: int) -> dict[str, jnp.ndarray]:
        prefix = f"layer_{n}."
        return {k_[len(prefix) :]: v_ for k_, v_ in params.items() if k_.startswith(prefix)}

    def new_layer() -> dict[str, jnp.ndarray]:
        nonlocal key
        lp: dict[str, jnp.ndarray] = {}
        key, k1 = _split(key)
        lp["g_mha"] = jnp.ones((cfg.hidden,), jnp.float32)
        lp["g_mlp"] = jnp.ones((cfg.hidden,), jnp.float32)
        for e in range(cfg.heads):
            for mat, width in (("wq", cfg.k), ("wk", cfg.k), ("wv", cfg.v)):
                key, k1 = _split(key)
                lp[f"head_{e}.{mat}"] = init_fn(k1, (cfg.hidden, width))
        key, ko = _split(key)
        key, k2w = _split(key)
        key, k2b = _split(key)
        if zero_constrained:  # Thm 3.6
            lp["wo"] = jnp.zeros((cfg.heads * cfg.v, cfg.hidden), jnp.float32)
            lp["w2"] = jnp.zeros((cfg.mlp, cfg.hidden), jnp.float32)
            lp["b2"] = jnp.zeros((cfg.hidden,), jnp.float32)
        else:
            lp["wo"] = init_fn(ko, (cfg.heads * cfg.v, cfg.hidden))
            lp["w2"] = init_fn(k2w, (cfg.mlp, cfg.hidden))
            lp["b2"] = init_fn(k2b, (cfg.hidden,))
        key, k1w = _split(key)
        key, k1b = _split(key)
        lp["w1"] = init_fn(k1w, (cfg.hidden, cfg.mlp))
        lp["b1"] = init_fn(k1b, (cfg.mlp,))
        return lp

    layers = [old_layer(n) for n in range(cfg.layers)]
    for _ in range(count):
        layers.insert(pos, new_layer())
    for n, lp in enumerate(layers):
        for k_, v_ in lp.items():
            out[f"layer_{n}.{k_}"] = v_
    return dataclasses.replace(cfg, layers=new_n), out


# ---------------------------------------------------------------------------
# Composition / op dispatch (shared vocabulary with the Rust coordinator)
# ---------------------------------------------------------------------------


def apply_op(
    cfg: ModelConfig,
    params: Params,
    op: dict[str, Any],
    *,
    key: jax.Array | None = None,
    init_fn: InitFn = default_init,
    zero_constrained: bool = True,
) -> tuple[ModelConfig, Params]:
    """Apply one schedule op (configs.OP_KINDS) to (cfg, params)."""
    kind = op["op"]
    kw = dict(key=key, init_fn=init_fn, zero_constrained=zero_constrained)
    if kind == "mlp":
        return expand_mlp(cfg, params, int(op["p"]), **kw)
    if kind == "heads_add":
        return add_heads(cfg, params, int(op.get("count", 1)), **kw)
    if kind == "heads_expand":
        return expand_heads(cfg, params, int(op["v"]), **kw)
    if kind == "attn_expand":
        return expand_attention(cfg, params, int(op["k"]), **kw)
    if kind == "hidden":
        return expand_hidden(cfg, params, int(op["h"]), **kw)
    if kind == "layers_add":
        return add_layers(cfg, params, int(op.get("count", 1)), op.get("position", "top"), **kw)
    raise ValueError(f"unknown op kind {kind!r}")


def apply_ops(
    cfg: ModelConfig,
    params: Params,
    ops: list[dict[str, Any]] | tuple[dict[str, Any], ...],
    *,
    key: jax.Array | None = None,
    init_fn: InitFn = default_init,
) -> tuple[ModelConfig, Params]:
    """Apply a composed sequence of ops (Section 3: transformations compose)."""
    key = jax.random.PRNGKey(0) if key is None else key
    for op in ops:
        key, sub = _split(key)
        new_cfg = apply_op_to_config(cfg, op)  # validates dimension monotonicity
        cfg, params = apply_op(cfg, params, op, key=sub, init_fn=init_fn)
        assert cfg == new_cfg, f"config drift applying {op}: {cfg} != {new_cfg}"
    return cfg, params
