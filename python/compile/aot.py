"""AOT: lower every growth-schedule stage to HLO text + manifest.json.

This is the single build-time entry point (`make artifacts`). For each stage
of the growth schedule it lowers

    fwd(*params, tokens)            -> (logits,)
    step(*params, tokens, targets)  -> (loss, *grads)

to **HLO text** (xla_extension 0.5.1 rejects jax>=0.5 serialized protos:
64-bit instruction ids; the text parser reassigns ids — see
/opt/xla-example/README.md) and writes `manifest.json` describing stage
configs, the canonical parameter order, and artifact file names. The Rust
runtime (rust/src/runtime/) consumes only this directory; Python never runs
again after this script.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import GrowthSchedule, ModelConfig, param_specs
from .model import make_fwd, make_step

DEFAULT_SCHEDULE = os.path.join(os.path.dirname(__file__), "..", "..", "configs", "growth_default.json")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stage(cfg: ModelConfig, batch: int, kernels: str) -> tuple[str, str]:
    """Return (fwd_hlo_text, step_hlo_text) for one stage config."""
    specs = param_specs(cfg)
    param_args = [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in specs]
    tokens = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32)
    targets = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32)
    fwd = jax.jit(make_fwd(cfg, kernels=kernels)).lower(*param_args, tokens)
    step = jax.jit(make_step(cfg, kernels=kernels)).lower(*param_args, tokens, targets)
    return to_hlo_text(fwd), to_hlo_text(step)


def build_manifest(sched: GrowthSchedule, kernels: str) -> dict:
    suffix = "" if kernels == "jnp" else f".{kernels}"
    stages = []
    for st in sched.stages:
        stages.append(
            {
                "name": st.name,
                "steps": st.steps,
                "apply": list(st.apply),
                "config": st.config.to_dict(),
                "params": [{"name": n, "shape": list(s)} for n, s in param_specs(st.config)],
                "num_params": st.config.num_params(),
                "fwd": f"{st.name}{suffix}.fwd.hlo.txt",
                "step": f"{st.name}{suffix}.step.hlo.txt",
            }
        )
    return {
        "version": 1,
        "schedule": sched.name,
        "batch": sched.batch,
        "kernels": kernels,
        "stages": stages,
    }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--schedule", default=DEFAULT_SCHEDULE, help="growth schedule JSON")
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument("--kernels", default="jnp", choices=("jnp", "pallas"), help="compute-path variant")
    ap.add_argument(
        "--manifest-name",
        default=None,
        help="manifest file name (default: manifest.json for jnp, manifest.<kernels>.json otherwise)",
    )
    args = ap.parse_args(argv)

    sched = GrowthSchedule.load(args.schedule)
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = build_manifest(sched, args.kernels)

    seen: dict[tuple, tuple[str, str]] = {}
    for st, entry in zip(sched.stages, manifest["stages"]):
        cfg_key = tuple(sorted(st.config.to_dict().items()))
        if cfg_key in seen:  # identical configs share artifacts
            entry["fwd"], entry["step"] = seen[cfg_key]
            print(f"[aot] {st.name}: reusing artifacts for identical config", file=sys.stderr)
            continue
        print(
            f"[aot] lowering {st.name} ({args.kernels}): {st.config.to_dict()} "
            f"({st.config.num_params():,} params)",
            file=sys.stderr,
        )
        fwd_text, step_text = lower_stage(st.config, sched.batch, args.kernels)
        for fname, text in ((entry["fwd"], fwd_text), (entry["step"], step_text)):
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
        seen[cfg_key] = (entry["fwd"], entry["step"])

    mname = args.manifest_name or ("manifest.json" if args.kernels == "jnp" else f"manifest.{args.kernels}.json")
    with open(os.path.join(args.out_dir, mname), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {mname} ({len(sched.stages)} stages) to {args.out_dir}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
