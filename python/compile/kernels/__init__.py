"""L1 Pallas kernels (build-time only) and their pure-jnp oracles."""

from .attention import pallas_attention
from .mlp import pallas_mlp
from .ref import MASK_VALUE, ref_attention, ref_mlp, ref_rmsnorm
from .rmsnorm import pallas_rmsnorm

__all__ = [
    "MASK_VALUE",
    "pallas_attention",
    "pallas_mlp",
    "pallas_rmsnorm",
    "ref_attention",
    "ref_mlp",
    "ref_rmsnorm",
]
