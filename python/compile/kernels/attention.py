"""Blocked causal attention as a Pallas kernel (flash-attention recurrence).

Hardware adaptation (DESIGN.md §5): the CUDA flash-attention formulation
(threadblocks over Q tiles, K/V streamed through shared memory) is re-thought
for TPU: the grid walks (batch*heads, q-tiles); each grid step holds one
`(block_q, dk)` Q tile in VMEM and streams `(block_kv, dk)` / `(block_kv, dv)`
K/V tiles with the online-softmax running accumulator. `BlockSpec` expresses
the HBM<->VMEM schedule that CUDA would express with threadblock indexing.
Tile defaults are MXU-shaped (128x128) and clamp to the problem size.

Must run with interpret=True on this image: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Numerics are validated
against `ref.ref_attention` by pytest (hypothesis shape sweep).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MASK_VALUE


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_kv: int, scale: float, causal: bool):
    """One grid step: one (block_q, dk) Q tile vs all needed K/V tiles."""
    block_q, dk = q_ref.shape
    seq, dv = v_ref.shape
    qi = pl.program_id(1)

    q = q_ref[...].astype(jnp.float32) * scale

    def body(j, carry):
        acc, m_prev, l_prev = carry
        k_tile = pl.load(k_ref, (pl.dslice(j * block_kv, block_kv), slice(None))).astype(jnp.float32)
        v_tile = pl.load(v_ref, (pl.dslice(j * block_kv, block_kv), slice(None))).astype(jnp.float32)
        scores = q @ k_tile.T  # [block_q, block_kv]
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
            k_pos = j * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
            scores = jnp.where(q_pos >= k_pos, scores, MASK_VALUE)
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v_tile
        return acc, m_new, l_new

    num_kv = seq // block_kv
    if causal:
        # Only tiles that intersect the causal triangle: j*block_kv <= last q row.
        # With block_q == block_kv this is j <= qi; keep general.
        upper = jnp.minimum(((qi + 1) * block_q + block_kv - 1) // block_kv, num_kv)
    else:
        upper = num_kv
    init = (
        jnp.zeros((block_q, dv), jnp.float32),
        jnp.full((block_q,), -jnp.inf, jnp.float32),
        jnp.zeros((block_q,), jnp.float32),
    )
    acc, _, l = jax.lax.fori_loop(0, upper, body, init)
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def _attention_forward(q, k, v, causal: bool, block_q: int, block_kv: int) -> jnp.ndarray:
    bh, seq, dk = q.shape
    dv = v.shape[-1]
    scale = 1.0 / float(dk) ** 0.5
    kernel = functools.partial(_attention_kernel, block_kv=block_kv, scale=scale, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(bh, seq // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, dk), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, seq, dk), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, seq, dv), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dv), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, dv), q.dtype),
        interpret=True,
    )(q, k, v)


# Backward pass: interpret-mode pallas_call is not differentiable under AOT
# lowering (program_id has no grid context when jax re-traces the kernel for
# the VJP), so the kernel carries a custom_vjp whose backward is the vjp of
# the *reference* attention — exact same math, XLA-fused. On a real TPU this
# is where a flash-attention backward kernel would slot in.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _attention(q, k, v, causal: bool, block_q: int, block_kv: int):
    return _attention_forward(q, k, v, causal, block_q, block_kv)


def _attention_fwd_rule(q, k, v, causal, block_q, block_kv):
    return _attention_forward(q, k, v, causal, block_q, block_kv), (q, k, v)


def _attention_bwd_rule(causal, block_q, block_kv, res, g):
    from .ref import ref_attention

    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref_attention(q_, k_, v_, causal=causal), q, k, v)
    return vjp(g)


_attention.defvjp(_attention_fwd_rule, _attention_bwd_rule)


def pallas_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_kv: int = 128,
) -> jnp.ndarray:
    """Blocked attention over [bh, s, d*] inputs; matches ref_attention.

    q, k: [bh, s, dk]; v: [bh, s, dv] -> [bh, s, dv]. `s` must be divisible
    by the (clamped) block sizes; the model pads sequences to multiples of
    the tile size at the call site if needed.
    """
    seq = q.shape[-2]
    block_q = min(block_q, seq)
    block_kv = min(block_kv, seq)
    if seq % block_q or seq % block_kv:
        raise ValueError(f"seq={seq} not divisible by blocks ({block_q},{block_kv})")
    return _attention(q, k, v, causal, block_q, block_kv)


def vmem_footprint_bytes(seq: int, dk: int, dv: int, block_q: int = 128, block_kv: int = 128, itemsize: int = 4) -> int:
    """Static VMEM estimate per grid step (for DESIGN/EXPERIMENTS §Perf).

    Counts the Q tile, one K and one V streaming tile, the score tile, the
    accumulator, and the K/V block windows Pallas keeps resident (full-seq
    K/V specs are conservative upper bounds here: seq*(dk+dv)).
    """
    block_q = min(block_q, seq)
    block_kv = min(block_kv, seq)
    tiles = (
        block_q * dk  # q tile
        + seq * dk  # k window (conservative: full-seq spec)
        + seq * dv  # v window
        + block_q * block_kv  # score tile
        + block_q * dv  # accumulator
        + 2 * block_q  # m, l
    )
    return tiles * itemsize
