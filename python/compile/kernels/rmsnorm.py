"""RMSNorm as a Pallas kernel (paper Eq. 5).

Row-tiled: each grid step normalizes a `(block_rows, h)` activation tile in
VMEM against the scaling vector g. Elementwise + row-reduction only (VPU
work, no MXU); included both as the simplest exemplar of the kernel
interface and because Thm 3.5's sqrt(h)/sqrt(h_hat) norm-scaling is the
subtlest part of the hidden-dimension expansion proof — having the norm as
a standalone kernel lets pytest probe it in isolation.

interpret=True on this image (see attention.py). Oracle: ref.ref_rmsnorm.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * g / jnp.sqrt(ms + eps)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def pallas_rmsnorm(x: jnp.ndarray, g: jnp.ndarray, *, block_rows: int = 128, eps: float = 0.0) -> jnp.ndarray:
    """RMSNorm over [rows, h]; matches ref_rmsnorm."""
    rows, h = x.shape
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        raise ValueError(f"rows={rows} not divisible by block_rows={block_rows}")
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, h), x.dtype),
        interpret=True,
    )(x, g)
