"""Fused ReLU-MLP as a Pallas kernel: ReLU(x W1 + b1) W2 + b2 (paper Eq. 3).

TPU mapping: the grid walks row tiles of the flattened activations; each
grid step keeps one `(block_rows, h)` activation tile in VMEM and loops over
`p`-tiles of the internal dimension, accumulating
`acc += ReLU(x @ W1[:, j] + b1[j]) @ W2[j, :]`. Because ReLU is elementwise
over the internal dimension, tiling p is *exact* (no recurrence needed, in
contrast to attention's online softmax). The W1/W2 column/row tiles stream
HBM->VMEM via pl.load; the MXU sees (block_rows x h) @ (h x block_p) and
(block_rows x block_p) @ (block_p x h) matmuls.

interpret=True on this image (see attention.py). Oracle: ref.ref_mlp.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, *, block_p: int):
    block_rows, h = x_ref.shape
    p = b1_ref.shape[0]
    x = x_ref[...].astype(jnp.float32)

    def body(j, acc):
        w1_tile = pl.load(w1_ref, (slice(None), pl.dslice(j * block_p, block_p))).astype(jnp.float32)
        b1_tile = pl.load(b1_ref, (pl.dslice(j * block_p, block_p),)).astype(jnp.float32)
        w2_tile = pl.load(w2_ref, (pl.dslice(j * block_p, block_p), slice(None))).astype(jnp.float32)
        hid = jnp.maximum(x @ w1_tile + b1_tile, 0.0)
        return acc + hid @ w2_tile

    acc = jax.lax.fori_loop(0, p // block_p, body, jnp.zeros((block_rows, h), jnp.float32))
    o_ref[...] = (acc + b2_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _mlp_forward(x, w1, b1, w2, b2, block_rows: int, block_p: int) -> jnp.ndarray:
    rows, h = x.shape
    p = b1.shape[0]
    kernel = functools.partial(_mlp_kernel, block_p=block_p)
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
            pl.BlockSpec((h, p), lambda i: (0, 0)),
            pl.BlockSpec((p,), lambda i: (0,)),
            pl.BlockSpec((p, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, h), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)


# custom_vjp: same rationale as attention.py — interpret-mode pallas_call
# cannot be re-traced for the VJP under AOT lowering, so backward is the vjp
# of the reference MLP (identical math, XLA-fused).
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _mlp(x, w1, b1, w2, b2, block_rows: int, block_p: int):
    return _mlp_forward(x, w1, b1, w2, b2, block_rows, block_p)


def _mlp_fwd_rule(x, w1, b1, w2, b2, block_rows, block_p):
    return _mlp_forward(x, w1, b1, w2, b2, block_rows, block_p), (x, w1, b1, w2, b2)


def _mlp_bwd_rule(block_rows, block_p, res, g):
    from .ref import ref_mlp

    _, vjp = jax.vjp(ref_mlp, *res)
    return vjp(g)


_mlp.defvjp(_mlp_fwd_rule, _mlp_bwd_rule)


def pallas_mlp(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
    *,
    block_rows: int = 128,
    block_p: int = 128,
) -> jnp.ndarray:
    """Fused MLP over [rows, h] activations; matches ref_mlp.

    x: [rows, h]; w1: [h, p]; b1: [p]; w2: [p, h]; b2: [h] -> [rows, h].
    """
    rows = x.shape[0]
    p = b1.shape[0]
    block_rows = min(block_rows, rows)
    block_p = min(block_p, p)
    if rows % block_rows or p % block_p:
        raise ValueError(f"rows={rows}, p={p} not divisible by blocks ({block_rows},{block_p})")
    return _mlp(x, w1, b1, w2, b2, block_rows, block_p)


def vmem_footprint_bytes(h: int, p: int, block_rows: int = 128, block_p: int = 128, itemsize: int = 4) -> int:
    """Static VMEM estimate per grid step (EXPERIMENTS §Perf)."""
    block_p = min(block_p, p)
    tiles = (
        block_rows * h  # x tile
        + h * block_p  # w1 tile
        + block_p  # b1 tile
        + block_p * h  # w2 tile
        + h  # b2
        + block_rows * block_p  # hidden tile
        + block_rows * h  # accumulator
    )
    return tiles * itemsize
