"""Pure-jnp oracles for the Pallas kernels.

These are the *correctness ground truth* for L1: pytest asserts the Pallas
kernels (interpret=True) match these references to tight tolerances across
hypothesis-swept shapes. They are also the default compute path used by the
training artifacts (XLA-CPU fuses these well; interpret-mode Pallas inside
the train step would only add CPU simulation overhead — see DESIGN.md §2).

All functions follow the paper's formalization (Section 2, Eqs. 3-5).
"""

from __future__ import annotations

import jax.numpy as jnp

#: Additive mask value for disallowed (non-causal) attention logits.
#: Finite (not -inf) so that fully-masked tiles in the blocked kernel remain
#: NaN-free; any causal row always has >= 1 unmasked entry so the softmax is
#: unaffected at f32 precision.
MASK_VALUE = -1e30


def ref_rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 0.0) -> jnp.ndarray:
    """RMSNorm, paper Eq. 5: x_ij * g_j / sqrt(mean_j x_ij^2).

    The paper's definition has no epsilon; we keep an optional one (default
    0.0 to preserve the exactness of Thm 3.5's sqrt(h)/sqrt(h_hat) scaling).
    """
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * g / jnp.sqrt(ms + eps)


def ref_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal: bool = True) -> jnp.ndarray:
    """Scaled dot-product attention, paper Eq. 4, with optional causal mask.

    Shapes: q, k: [..., s, dk], v: [..., s, dv] -> [..., s, dv].
    The 1/sqrt(dk) scale uses the *static* dk of the inputs, which is what
    Thm 3.4's sqrt(k_hat)/sqrt(k) key-scaling compensates for.
    """
    dk = q.shape[-1]
    scores = jnp.einsum("...qd,...kd->...qk", q, k) / jnp.sqrt(jnp.float32(dk))
    if causal:
        s = q.shape[-2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask, scores, MASK_VALUE)
    weights = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return jnp.einsum("...qk,...kd->...qd", weights, v)


def ref_mlp(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray, w2: jnp.ndarray, b2: jnp.ndarray) -> jnp.ndarray:
    """Two-layer ReLU MLP, paper Eq. 3: ReLU(x W1 + b1) W2 + b2."""
    hid = jnp.maximum(x @ w1 + b1, 0.0)
    return hid @ w2 + b2
