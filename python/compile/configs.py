"""Architecture configs, growth schedules, and the canonical parameter order.

This module is the *contract* shared between the build-time Python side and
the Rust coordinator: `rust/src/config/` and `rust/src/params/` mirror the
structures defined here, and `artifacts/manifest.json` (emitted by aot.py)
is validated against them on the Rust side at load time.

The architecture hyper-parameters follow the paper's notation (Section 2):

    N  (layers)  number of transformer layers
    h  (hidden)  transformer layer input/output width
    E  (heads)   number of attention heads
    k            key/query width per head
    v            value width per head
    p  (mlp)     MLP internal width
    s  (seq)     sequence length
    vocab        input vocabulary == output dimension `o`
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters of one architecture *stage* (paper Section 2)."""

    layers: int  # N
    hidden: int  # h
    heads: int  # E
    k: int
    v: int
    mlp: int  # p
    seq: int  # s
    vocab: int  # input vocab size and output dim o

    def validate(self) -> None:
        for name in ("layers", "hidden", "heads", "k", "v", "mlp", "seq", "vocab"):
            val = getattr(self, name)
            if not isinstance(val, int) or val <= 0:
                raise ValueError(f"ModelConfig.{name} must be a positive int, got {val!r}")

    def to_dict(self) -> dict[str, int]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict[str, Any]) -> "ModelConfig":
        cfg = ModelConfig(**{f.name: int(d[f.name]) for f in dataclasses.fields(ModelConfig)})
        cfg.validate()
        return cfg

    def num_params(self) -> int:
        """Total scalar parameter count."""
        per_layer = (
            self.hidden  # g_mha
            + self.heads * self.hidden * (2 * self.k + self.v)  # wq, wk, wv
            + self.heads * self.v * self.hidden  # wo
            + self.hidden  # g_mlp
            + self.hidden * self.mlp  # w1
            + self.mlp  # b1
            + self.mlp * self.hidden  # w2
            + self.hidden  # b2
        )
        return (
            self.vocab * self.hidden  # embed
            + self.seq * self.hidden  # pos
            + self.layers * per_layer
            + self.hidden * self.vocab  # w_out
        )


def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Canonical (name, shape) parameter order — DESIGN.md Section 7.

    The Rust `ParamStore` reproduces this order exactly; the AOT artifacts
    take parameters as positional inputs in this order.
    """
    specs: list[tuple[str, tuple[int, ...]]] = [
        ("embed", (cfg.vocab, cfg.hidden)),
        ("pos", (cfg.seq, cfg.hidden)),
    ]
    for n in range(cfg.layers):
        specs.append((f"layer_{n}.g_mha", (cfg.hidden,)))
        for e in range(cfg.heads):
            specs.append((f"layer_{n}.head_{e}.wq", (cfg.hidden, cfg.k)))
            specs.append((f"layer_{n}.head_{e}.wk", (cfg.hidden, cfg.k)))
            specs.append((f"layer_{n}.head_{e}.wv", (cfg.hidden, cfg.v)))
        specs.append((f"layer_{n}.wo", (cfg.heads * cfg.v, cfg.hidden)))
        specs.append((f"layer_{n}.g_mlp", (cfg.hidden,)))
        specs.append((f"layer_{n}.w1", (cfg.hidden, cfg.mlp)))
        specs.append((f"layer_{n}.b1", (cfg.mlp,)))
        specs.append((f"layer_{n}.w2", (cfg.mlp, cfg.hidden)))
        specs.append((f"layer_{n}.b2", (cfg.hidden,)))
    specs.append(("w_out", (cfg.hidden, cfg.vocab)))
    return specs


# ---------------------------------------------------------------------------
# Growth schedules
# ---------------------------------------------------------------------------

#: The transformation-op vocabulary shared with the Rust coordinator.
#: Each op maps a ModelConfig to the post-transformation ModelConfig.
#: (The *parameter surgery* itself lives in transforms.py / rust/src/expand/.)
OP_KINDS = ("mlp", "heads_add", "heads_expand", "attn_expand", "hidden", "layers_add")


def apply_op_to_config(cfg: ModelConfig, op: dict[str, Any]) -> ModelConfig:
    """Return the config that results from applying `op` (dimension-level)."""
    kind = op["op"]
    if kind == "mlp":
        new_p = int(op["p"])
        if new_p <= cfg.mlp:
            raise ValueError(f"mlp expansion must grow p: {cfg.mlp} -> {new_p}")
        return dataclasses.replace(cfg, mlp=new_p)
    if kind == "heads_add":
        count = int(op.get("count", 1))
        if count < 1:
            raise ValueError("heads_add count must be >= 1")
        return dataclasses.replace(cfg, heads=cfg.heads + count)
    if kind == "heads_expand":
        new_v = int(op["v"])
        if new_v <= cfg.v:
            raise ValueError(f"heads expansion must grow v: {cfg.v} -> {new_v}")
        return dataclasses.replace(cfg, v=new_v)
    if kind == "attn_expand":
        new_k = int(op["k"])
        if new_k <= cfg.k:
            raise ValueError(f"attention expansion must grow k: {cfg.k} -> {new_k}")
        return dataclasses.replace(cfg, k=new_k)
    if kind == "hidden":
        new_h = int(op["h"])
        if new_h <= cfg.hidden:
            raise ValueError(f"hidden expansion must grow h: {cfg.hidden} -> {new_h}")
        return dataclasses.replace(cfg, hidden=new_h)
    if kind == "layers_add":
        count = int(op.get("count", 1))
        if count < 1:
            raise ValueError("layers_add count must be >= 1")
        return dataclasses.replace(cfg, layers=cfg.layers + count)
    raise ValueError(f"unknown transformation op kind: {kind!r}")


@dataclasses.dataclass(frozen=True)
class Stage:
    """One growth-schedule stage: train `steps` steps under `config`.

    `apply` holds the transformation ops executed at the *entry* boundary of
    this stage (empty for stage 0).
    """

    name: str
    config: ModelConfig
    steps: int
    apply: tuple[dict[str, Any], ...]


@dataclasses.dataclass(frozen=True)
class GrowthSchedule:
    name: str
    batch: int
    stages: tuple[Stage, ...]

    @staticmethod
    def from_dict(d: dict[str, Any]) -> "GrowthSchedule":
        base = ModelConfig.from_dict({**d["base"], "seq": d["seq"], "vocab": d["vocab"]})
        stages: list[Stage] = []
        cfg = base
        for i, sd in enumerate(d["stages"]):
            ops = tuple(sd.get("apply", ()))
            if i == 0 and ops:
                raise ValueError("stage 0 cannot have `apply` ops (nothing to expand yet)")
            for op in ops:
                cfg = apply_op_to_config(cfg, op)
            stages.append(Stage(name=f"stage{i}", config=cfg, steps=int(sd["steps"]), apply=ops))
        sched = GrowthSchedule(name=str(d.get("name", "unnamed")), batch=int(d.get("batch", 8)), stages=tuple(stages))
        if not sched.stages:
            raise ValueError("schedule must have at least one stage")
        return sched

    @staticmethod
    def load(path: str) -> "GrowthSchedule":
        with open(path) as f:
            return GrowthSchedule.from_dict(json.load(f))
