"""L2: the paper-faithful transformer (Section 2, Eqs. 1-5) in JAX.

Build-time only — this module is lowered to HLO text by aot.py and executed
from Rust via PJRT; it is never imported on the training path.

Faithfulness notes (these all matter for the function-preservation proofs):
  * pre-norm residual blocks exactly as Eq. 2;
  * RMSNorm (Eq. 5) with *no epsilon* by default — Thm 3.5's
    sqrt(h)/sqrt(h_hat) norm-scaling is exact only for eps=0;
  * per-head W^Q/W^K/W^V with head outputs concatenated before a single
    W^O (Eq. 4) — Defs 3.2/3.3 describe surgery on the E*v-row W^O;
  * 1/sqrt(k) score scaling with the *static* k (Eq. 4), compensated by
    Thm 3.4's key scaling on expansion;
  * ReLU MLP with biases (Eq. 3);
  * learned positional embedding P added once at the input (Eq. 1);
  * final projection W^out with *no* final normalization (Eq. 1) and no
    embed/W^out weight tying (their expansion constraints differ).

We add a batch dimension and causal masking (the paper formalizes a single
sequence and omits the mask; both are orthogonal to the theorems — the mask
is applied to the score matrix *after* scaling, so the Thm 3.4 algebra is
unchanged, and preservation holds per batch row independently).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .configs import ModelConfig, param_specs
from .kernels import pallas_attention, pallas_mlp, ref_attention, ref_mlp, ref_rmsnorm

Params = dict[str, jnp.ndarray]


def init_params(cfg: ModelConfig, seed: int = 0, scale: float = 0.02) -> Params:
    """Random-normal init (scale*N(0,1)), norm gains at 1. Matches rust init
    given the same algorithm; tests only rely on distributional shape."""
    key = jax.random.PRNGKey(seed)
    params: Params = {}
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("g_mha", "g_mlp")):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("b1", "b2")):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            params[name] = scale * jax.random.normal(sub, shape, jnp.float32)
    return params


def flatten_params(cfg: ModelConfig, params: Params) -> list[jnp.ndarray]:
    """Canonical-order flat list (the AOT artifact's positional inputs)."""
    out = []
    for name, shape in param_specs(cfg):
        arr = params[name]
        if tuple(arr.shape) != tuple(shape):
            raise ValueError(f"param {name}: expected shape {shape}, got {arr.shape}")
        out.append(arr)
    return out


def unflatten_params(cfg: ModelConfig, flat: list[jnp.ndarray]) -> Params:
    specs = param_specs(cfg)
    if len(flat) != len(specs):
        raise ValueError(f"expected {len(specs)} params, got {len(flat)}")
    return {name: arr for (name, _), arr in zip(specs, flat)}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _mha(cfg: ModelConfig, params: Params, n: int, x: jnp.ndarray, kernels: str) -> jnp.ndarray:
    """Multi-head attention, Eq. 4. x: [B, s, h] -> [B, s, h]."""
    B, s, h = x.shape
    wq = jnp.stack([params[f"layer_{n}.head_{e}.wq"] for e in range(cfg.heads)])  # [E, h, k]
    wk = jnp.stack([params[f"layer_{n}.head_{e}.wk"] for e in range(cfg.heads)])
    wv = jnp.stack([params[f"layer_{n}.head_{e}.wv"] for e in range(cfg.heads)])  # [E, h, v]
    q = jnp.einsum("bsh,ehk->besk", x, wq)
    k = jnp.einsum("bsh,ehk->besk", x, wk)
    v = jnp.einsum("bsh,ehv->besv", x, wv)
    if kernels == "pallas":
        bh = B * cfg.heads
        heads = pallas_attention(
            q.reshape(bh, s, cfg.k), k.reshape(bh, s, cfg.k), v.reshape(bh, s, cfg.v), causal=True
        ).reshape(B, cfg.heads, s, cfg.v)
    else:
        heads = ref_attention(q, k, v, causal=True)  # [B, E, s, v]
    concat = heads.transpose(0, 2, 1, 3).reshape(B, s, cfg.heads * cfg.v)  # [H_1 ... H_E]
    return concat @ params[f"layer_{n}.wo"]


def _mlp(cfg: ModelConfig, params: Params, n: int, x: jnp.ndarray, kernels: str) -> jnp.ndarray:
    """MLP, Eq. 3. x: [B, s, h] -> [B, s, h]."""
    B, s, h = x.shape
    w1, b1 = params[f"layer_{n}.w1"], params[f"layer_{n}.b1"]
    w2, b2 = params[f"layer_{n}.w2"], params[f"layer_{n}.b2"]
    if kernels == "pallas":
        return pallas_mlp(x.reshape(B * s, h), w1, b1, w2, b2).reshape(B, s, h)
    return ref_mlp(x, w1, b1, w2, b2)


def forward(cfg: ModelConfig, params: Params, tokens: jnp.ndarray, *, kernels: str = "jnp") -> jnp.ndarray:
    """TransformerArchitecture (Eq. 1): tokens [B, s] int32 -> logits [B, s, vocab]."""
    if kernels not in ("jnp", "pallas"):
        raise ValueError(f"kernels must be 'jnp' or 'pallas', got {kernels!r}")
    x = params["embed"][tokens] + params["pos"][None, :, :]  # I + P
    for n in range(cfg.layers):
        x = x + _mha(cfg, params, n, ref_rmsnorm(x, params[f"layer_{n}.g_mha"]), kernels)  # I'_n (Eq. 2)
        x = x + _mlp(cfg, params, n, ref_rmsnorm(x, params[f"layer_{n}.g_mlp"]), kernels)
    return x @ params["w_out"]


def loss_fn(cfg: ModelConfig, params: Params, tokens: jnp.ndarray, targets: jnp.ndarray, *, kernels: str = "jnp") -> jnp.ndarray:
    """Mean next-token cross-entropy. targets: [B, s] int32 (already shifted)."""
    logits = forward(cfg, params, tokens, kernels=kernels)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# AOT entrypoints (positional flat-param signatures)
# ---------------------------------------------------------------------------


def make_fwd(cfg: ModelConfig, *, kernels: str = "jnp") -> Callable:
    """fwd(*flat_params, tokens) -> (logits,) — positional for HLO lowering."""

    def fwd(*args):
        flat, tokens = list(args[:-1]), args[-1]
        return (forward(cfg, unflatten_params(cfg, flat), tokens, kernels=kernels),)

    return fwd


def make_step(cfg: ModelConfig, *, kernels: str = "jnp") -> Callable:
    """step(*flat_params, tokens, targets) -> (loss, *grads).

    Gradients come back to Rust, which owns the optimizer (DESIGN.md §2:
    optimizer moments must undergo the same expansion surgery as params).
    """

    def step(*args):
        flat, tokens, targets = list(args[:-2]), args[-2], args[-1]

        def loss_of(flat_p):
            return loss_fn(cfg, unflatten_params(cfg, flat_p), tokens, targets, kernels=kernels)

        loss, grads = jax.value_and_grad(loss_of)(flat)
        return (loss, *grads)

    return step
